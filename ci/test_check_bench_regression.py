#!/usr/bin/env python3
"""Smoke tests for check_bench_regression.py against synthetic reports.

Stdlib-only (unittest + tempfile); run directly or via
`python3 -m unittest discover ci` in the CI smoke job. Each case writes
a minimal synthetic BENCH_gemv.json / BENCH_serving.json and asserts
the gate's exit code, so the SKIP-vs-FAIL contract (old reports skip,
degenerate values fail) cannot rot silently.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from check_bench_regression import main  # noqa: E402


def good_report(**overrides):
    """A report that clears every gate; override fields per case."""
    report = {
        "int4_lut_speedup": 2.5,
        "int4_simd_speedup": 4.0,
        "simd_available": True,
        "metrics_overhead": {
            "off_tokens_per_s": 1000.0,
            "on_tokens_per_s": 990.0,
            "overhead_frac": 0.01,
        },
        "failpoint_overhead": {
            "plain_tokens_per_s": 1000.0,
            "off_tokens_per_s": 997.0,
            "overhead_frac": 0.003,
        },
    }
    for key, value in overrides.items():
        if value is _ABSENT:
            report.pop(key, None)
        else:
            report[key] = value
    return report


_ABSENT = object()


class GateTest(unittest.TestCase):
    def run_gate(self, report, extra_args=()):
        with tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False, encoding="utf-8"
        ) as f:
            json.dump(report, f)
            path = f.name
        try:
            return main([path, *extra_args])
        finally:
            os.unlink(path)

    def test_good_report_passes(self):
        self.assertEqual(self.run_gate(good_report()), 0)

    def test_lut_below_floor_fails(self):
        self.assertEqual(self.run_gate(good_report(int4_lut_speedup=1.1)), 1)

    def test_missing_lut_speedup_fails(self):
        self.assertEqual(self.run_gate(good_report(int4_lut_speedup=_ABSENT)), 1)

    def test_simd_tier_missing_is_skipped(self):
        # Reports from before the SIMD tier skip, not fail.
        report = good_report(int4_simd_speedup=_ABSENT, simd_available=_ABSENT)
        self.assertEqual(self.run_gate(report), 0)

    def test_simd_unavailable_is_skipped(self):
        report = good_report(int4_simd_speedup=1.0, simd_available=False)
        self.assertEqual(self.run_gate(report), 0)

    def test_simd_below_floor_fails(self):
        self.assertEqual(self.run_gate(good_report(int4_simd_speedup=2.0)), 1)

    def test_metrics_overhead_missing_is_skipped(self):
        # Reports from before the telemetry tier skip, not fail.
        report = good_report(metrics_overhead=_ABSENT)
        self.assertEqual(self.run_gate(report), 0)

    def test_metrics_overhead_above_ceiling_fails(self):
        report = good_report(
            metrics_overhead={
                "off_tokens_per_s": 1000.0,
                "on_tokens_per_s": 950.0,
                "overhead_frac": 0.05,
            }
        )
        self.assertEqual(self.run_gate(report), 1)

    def test_metrics_overhead_below_ceiling_passes(self):
        report = good_report(
            metrics_overhead={
                "off_tokens_per_s": 1000.0,
                "on_tokens_per_s": 990.0,
                "overhead_frac": 0.01,
            }
        )
        self.assertEqual(self.run_gate(report), 0)

    def test_metrics_overhead_non_finite_fails(self):
        report = good_report(
            metrics_overhead={"overhead_frac": float("nan")}
        )
        self.assertEqual(self.run_gate(report), 1)

    def test_metrics_overhead_custom_ceiling(self):
        report = good_report(
            metrics_overhead={"overhead_frac": 0.05}
        )
        self.assertEqual(self.run_gate(report, ["--max-metrics-overhead", "0.10"]), 0)
        self.assertEqual(self.run_gate(report, ["--max-metrics-overhead", "0.02"]), 1)

    def test_failpoint_overhead_missing_is_skipped(self):
        # Reports from before the failpoint tier skip, not fail.
        report = good_report(failpoint_overhead=_ABSENT)
        self.assertEqual(self.run_gate(report), 0)

    def test_failpoint_overhead_above_ceiling_fails(self):
        report = good_report(
            failpoint_overhead={
                "plain_tokens_per_s": 1000.0,
                "off_tokens_per_s": 975.0,
                "overhead_frac": 0.025,
            }
        )
        self.assertEqual(self.run_gate(report), 1)

    def test_failpoint_overhead_below_ceiling_passes(self):
        report = good_report(
            failpoint_overhead={
                "plain_tokens_per_s": 1000.0,
                "off_tokens_per_s": 995.0,
                "overhead_frac": 0.005,
            }
        )
        self.assertEqual(self.run_gate(report), 0)

    def test_failpoint_overhead_non_finite_fails(self):
        report = good_report(
            failpoint_overhead={"overhead_frac": float("inf")}
        )
        self.assertEqual(self.run_gate(report), 1)

    def test_failpoint_overhead_custom_ceiling(self):
        report = good_report(
            failpoint_overhead={"overhead_frac": 0.02}
        )
        self.assertEqual(self.run_gate(report, ["--max-failpoint-overhead", "0.05"]), 0)
        self.assertEqual(self.run_gate(report, ["--max-failpoint-overhead", "0.01"]), 1)

    def run_serving_gate(self, serving_report, extra_args=()):
        with tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False, encoding="utf-8"
        ) as f:
            json.dump(serving_report, f)
            serving = f.name
        try:
            return self.run_gate(
                good_report(), ["--serving", serving, *extra_args]
            )
        finally:
            os.unlink(serving)

    def test_serving_tiers_gate(self):
        tier = {
            "concurrent_sessions": 100,
            "ttft_p50_ms": 1.0,
            "ttft_p99_ms": 2.0,
            "tokens_per_s": 500.0,
        }
        report = {"generation_tiers": [tier, dict(tier), dict(tier)]}
        self.assertEqual(self.run_serving_gate(report), 0)

    def test_serving_degenerate_tier_fails(self):
        bad = {
            "concurrent_sessions": 100,
            "ttft_p50_ms": float("nan"),
            "ttft_p99_ms": 2.0,
            "tokens_per_s": 500.0,
        }
        report = {"generation_tiers": [bad, dict(bad), dict(bad)]}
        self.assertEqual(self.run_serving_gate(report), 1)

    def good_serving_report(self, **overrides):
        """A serving report with generation + specdec tiers that clears
        every serving gate; override fields per case."""
        gen = {
            "concurrent_sessions": 100,
            "ttft_p50_ms": 1.0,
            "ttft_p99_ms": 2.0,
            "tokens_per_s": 500.0,
        }
        spec = {
            "draft_bits": 4,
            "concurrent_sessions": 1,
            "plain_tokens_per_s": 400.0,
            "spec_tokens_per_s": 600.0,
            "speedup": 1.5,
            "acceptance_rate": 0.8,
        }
        report = {
            "generation_tiers": [gen, dict(gen), dict(gen)],
            "specdec": [spec],
            "int4_specdec_speedup": 1.5,
        }
        for key, value in overrides.items():
            if value is _ABSENT:
                report.pop(key, None)
            else:
                report[key] = value
        return report

    def test_specdec_tier_passes(self):
        self.assertEqual(self.run_serving_gate(self.good_serving_report()), 0)

    def test_specdec_missing_is_skipped(self):
        # Serving reports from before the specdec tier skip, not fail.
        report = self.good_serving_report(
            specdec=_ABSENT, int4_specdec_speedup=_ABSENT
        )
        self.assertEqual(self.run_serving_gate(report), 0)

    def test_specdec_headline_below_floor_fails(self):
        report = self.good_serving_report(int4_specdec_speedup=1.05)
        self.assertEqual(self.run_serving_gate(report), 1)

    def test_specdec_custom_floor(self):
        report = self.good_serving_report(int4_specdec_speedup=1.1)
        self.assertEqual(
            self.run_serving_gate(report, ["--min-specdec-speedup", "1.0"]), 0
        )
        self.assertEqual(
            self.run_serving_gate(report, ["--min-specdec-speedup", "1.4"]), 1
        )

    def test_specdec_missing_headline_fails(self):
        # A specdec section without the headline is malformed, not old.
        report = self.good_serving_report(int4_specdec_speedup=_ABSENT)
        self.assertEqual(self.run_serving_gate(report), 1)

    def test_specdec_degenerate_tier_fails(self):
        for bad in (
            {"plain_tokens_per_s": 0.0},
            {"spec_tokens_per_s": float("nan")},
            {"acceptance_rate": 1.5},
        ):
            report = self.good_serving_report()
            report["specdec"][0].update(bad)
            self.assertEqual(self.run_serving_gate(report), 1)

    def test_specdec_empty_section_fails(self):
        report = self.good_serving_report(specdec=[])
        self.assertEqual(self.run_serving_gate(report), 1)


if __name__ == "__main__":
    unittest.main()
