#!/usr/bin/env python3
"""Bench regression gate for the packed kernel engine and the server.

Two checks, both wired into the CI bench-smoke job:

1. Kernel floor (positional REPORT): reads the BENCH_gemv.json report
   written by `cargo bench --bench perf_probe -- --gemv-json ...` and
   fails (exit 1) if the LUT-fused INT4 GEMV kernel is not at least
   MIN_SPEEDUP x faster than the scalar unpack-whole-row baseline on
   the fixed-iteration smoke run. This is the CI contract behind
   DESIGN.md §7: the LUT engine exists to be faster; a regression below
   the floor means the fused path has rotted into a slow path and must
   not merge silently.

   On hosts where the report says `simd_available` is true the same
   check also gates the SIMD tier: the INT4 SIMD GEMV must be at least
   MIN_SIMD x faster than scalar (DESIGN.md §9 / EXPERIMENTS.md E16).
   On hosts without AVX2/NEON the SIMD kernels fall back to the LUT
   path, the timing is a duplicate, and the tier is skipped — skipped,
   not failed, so the gate stays honest on feature-poor runners.
   Reports from before the SIMD tier existed (no `int4_simd_speedup`
   field) are likewise skipped with a notice.

2. Serving gate (--serving BENCH_serving.json): validates the
   continuous-batching generation tiers emitted by
   `perf_probe --serving-json` — at least three concurrency tiers, each
   with finite p50/p99 TTFT (p50 <= p99) and positive aggregate
   tokens/s. This is the DESIGN.md §8 contract: the streaming service
   must sustain 100/1k/10k concurrent sessions and report honest TTFT,
   and a tier that vanishes or degenerates (NaN timing, zero
   throughput) must not merge silently.

   The same serving report also carries the speculative-decoding tiers
   (`specdec` + `int4_specdec_speedup` headline). When present, each
   tier must have finite positive plain/speculative tokens/s and an
   acceptance rate in [0, 1], and the headline INT4-draft speedup at
   1 session must be at least --min-specdec-speedup (default 1.2) —
   greedy verification makes speculative output bit-identical to plain
   decoding, so the only reason to carry the draft model is speed, and
   a speculative path slower than the floor must not merge silently.
   Reports predating the tier (no `specdec` section) are skipped with
   a notice.

3. Telemetry overhead gate (same REPORT): the `metrics_overhead`
   object written by the gemv section times the INT4 decode with
   metrics recording off vs on; the gate fails if `overhead_frac`
   exceeds --max-metrics-overhead (default 0.03). This is the
   DESIGN.md §10 contract: telemetry must be cheap enough to leave on
   in a serving deployment. Reports from before the telemetry tier
   existed (no `metrics_overhead` field) are skipped with a notice.

4. Failpoint overhead gate (same REPORT): the `failpoint_overhead`
   object times the INT4 decode plain vs with a *disarmed* failpoint
   evaluated per token — the cost every serving decode step pays for
   the chaos harness when no fault plan is armed (one relaxed atomic
   load, DESIGN.md §12). The gate fails if `overhead_frac` exceeds
   --max-failpoint-overhead (default 0.01). Reports from before the
   failpoint tier (no `failpoint_overhead` field) are skipped with a
   notice.

Usage:
  check_bench_regression.py BENCH_gemv.json [--min 1.5] [--min-simd 3.0]
                            [--max-metrics-overhead 0.03]
                            [--max-failpoint-overhead 0.01]
                            [--serving BENCH_serving.json]
                            [--min-specdec-speedup 1.2]
"""

import argparse
import json
import math
import sys


def _load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def _finite(v):
    return isinstance(v, (int, float)) and math.isfinite(v)


def check_serving(path: str, min_specdec_speedup: float) -> int:
    try:
        report = _load(path)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read serving report {path}: {e}")
        return 1

    tiers = report.get("generation_tiers")
    if not isinstance(tiers, list) or len(tiers) < 3:
        n = len(tiers) if isinstance(tiers, list) else 0
        print(f"FAIL: {path} has {n} generation tiers; the gate requires >= 3")
        return 1

    failures = 0
    for tier in tiers:
        sessions = tier.get("concurrent_sessions")
        p50 = tier.get("ttft_p50_ms")
        p99 = tier.get("ttft_p99_ms")
        tps = tier.get("tokens_per_s")
        label = f"serving tier x{sessions}"
        if not (_finite(p50) and _finite(p99) and _finite(tps)):
            print(f"FAIL: {label}: non-finite metrics (p50={p50!r} p99={p99!r} tok/s={tps!r})")
            failures += 1
            continue
        if p50 < 0 or p99 < p50:
            print(f"FAIL: {label}: inconsistent TTFT percentiles p50={p50:.2f} p99={p99:.2f}")
            failures += 1
            continue
        if tps <= 0:
            print(f"FAIL: {label}: non-positive throughput {tps:.2f} tok/s")
            failures += 1
            continue
        print(f"{label}: ttft p50 {p50:.2f}ms p99 {p99:.2f}ms  {tps:.0f} tok/s")

    if failures:
        return 1
    print(f"OK: {len(tiers)} serving tiers clear the gate")
    return check_specdec(report, path, min_specdec_speedup)


def check_specdec(report, path: str, min_speedup: float) -> int:
    """Gate the speculative-decoding tiers of the serving report; SKIP
    (0) when the report predates them, FAIL (1) on degenerate tiers or
    a headline INT4-draft speedup below the floor."""
    tiers = report.get("specdec")
    headline = report.get("int4_specdec_speedup")
    if tiers is None and headline is None:
        print("SKIP: report predates the speculative-decoding tier (no 'specdec')")
        return 0
    if not isinstance(tiers, list) or not tiers:
        print(f"FAIL: {path} has an empty or malformed 'specdec' section")
        return 1

    failures = 0
    for tier in tiers:
        bits = tier.get("draft_bits")
        sessions = tier.get("concurrent_sessions")
        label = f"specdec tier int{bits} x{sessions}"
        plain = tier.get("plain_tokens_per_s")
        spec = tier.get("spec_tokens_per_s")
        acc = tier.get("acceptance_rate")
        if not (_finite(plain) and _finite(spec) and _finite(acc)):
            print(
                f"FAIL: {label}: non-finite metrics "
                f"(plain={plain!r} spec={spec!r} acceptance={acc!r})"
            )
            failures += 1
            continue
        if plain <= 0 or spec <= 0:
            print(
                f"FAIL: {label}: non-positive throughput "
                f"(plain {plain:.2f}, spec {spec:.2f} tok/s)"
            )
            failures += 1
            continue
        if not 0.0 <= acc <= 1.0:
            print(f"FAIL: {label}: acceptance rate {acc:.3f} outside [0, 1]")
            failures += 1
            continue
        print(
            f"{label}: plain {plain:.0f} -> spec {spec:.0f} tok/s  "
            f"acceptance {acc * 100.0:.1f}%"
        )
    if failures:
        return 1

    if not _finite(headline):
        print(f"FAIL: {path} has no finite 'int4_specdec_speedup' (got {headline!r})")
        return 1
    print(
        f"specdec headline: INT4 draft {headline:.2f}x plain at 1 session "
        f"(floor {min_speedup:.2f}x)"
    )
    if headline < min_speedup:
        print(
            f"FAIL: speculative decoding speedup {headline:.2f}x is below "
            f"the {min_speedup:.2f}x floor"
        )
        return 1
    print("OK: speculative decoding clears the speedup floor")
    return 0


def check_metrics_overhead(report, path: str, max_overhead: float) -> int:
    """Gate the telemetry-overhead tier; SKIP (0) when the report
    predates it, FAIL (1) on a non-finite or above-threshold fraction."""
    overhead = report.get("metrics_overhead")
    if overhead is None:
        print("SKIP: report predates the telemetry tier (no 'metrics_overhead')")
        return 0
    frac = overhead.get("overhead_frac") if isinstance(overhead, dict) else None
    if not _finite(frac):
        print(f"FAIL: {path} has non-finite 'metrics_overhead.overhead_frac' ({frac!r})")
        return 1
    off = overhead.get("off_tokens_per_s")
    on = overhead.get("on_tokens_per_s")
    detail = ""
    if _finite(off) and _finite(on):
        detail = f"  (off {off:.0f} vs on {on:.0f} tok/s)"
    print(
        f"telemetry overhead: {frac * 100.0:.2f}% of 1-token decode "
        f"(ceiling {max_overhead * 100.0:.2f}%){detail}"
    )
    if frac > max_overhead:
        print(
            f"FAIL: telemetry overhead {frac * 100.0:.2f}% exceeds the "
            f"{max_overhead * 100.0:.2f}% ceiling"
        )
        return 1
    print("OK: telemetry overhead clears the ceiling")
    return 0


def check_failpoint_overhead(report, path: str, max_overhead: float) -> int:
    """Gate the disarmed-failpoint overhead tier; SKIP (0) when the
    report predates it, FAIL (1) on a non-finite or above-threshold
    fraction."""
    overhead = report.get("failpoint_overhead")
    if overhead is None:
        print("SKIP: report predates the failpoint tier (no 'failpoint_overhead')")
        return 0
    frac = overhead.get("overhead_frac") if isinstance(overhead, dict) else None
    if not _finite(frac):
        print(f"FAIL: {path} has non-finite 'failpoint_overhead.overhead_frac' ({frac!r})")
        return 1
    plain = overhead.get("plain_tokens_per_s")
    off = overhead.get("off_tokens_per_s")
    detail = ""
    if _finite(plain) and _finite(off):
        detail = f"  (plain {plain:.0f} vs failpoint-off {off:.0f} tok/s)"
    print(
        f"disarmed-failpoint overhead: {frac * 100.0:.2f}% of 1-token decode "
        f"(ceiling {max_overhead * 100.0:.2f}%){detail}"
    )
    if frac > max_overhead:
        print(
            f"FAIL: disarmed-failpoint overhead {frac * 100.0:.2f}% exceeds "
            f"the {max_overhead * 100.0:.2f}% ceiling"
        )
        return 1
    print("OK: disarmed failpoints clear the overhead ceiling")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", help="path to BENCH_gemv.json")
    ap.add_argument(
        "--min",
        type=float,
        default=1.5,
        dest="min_speedup",
        help="minimum INT4 LUT-vs-scalar GEMV speedup (default 1.5)",
    )
    ap.add_argument(
        "--min-simd",
        type=float,
        default=3.0,
        dest="min_simd",
        help="minimum INT4 SIMD-vs-scalar GEMV speedup on SIMD-capable "
        "hosts (default 3.0); skipped when the report says "
        "simd_available is false or predates the SIMD tier",
    )
    ap.add_argument(
        "--max-metrics-overhead",
        type=float,
        default=0.03,
        dest="max_metrics_overhead",
        help="maximum fraction of 1-token decode throughput telemetry "
        "recording may cost (default 0.03); skipped when the report "
        "predates the telemetry tier",
    )
    ap.add_argument(
        "--max-failpoint-overhead",
        type=float,
        default=0.01,
        dest="max_failpoint_overhead",
        help="maximum fraction of 1-token decode throughput a *disarmed* "
        "failpoint check may cost (default 0.01); skipped when the "
        "report predates the failpoint tier",
    )
    ap.add_argument(
        "--serving",
        default=None,
        metavar="BENCH_serving.json",
        help="also gate the streaming-generation serving tiers",
    )
    ap.add_argument(
        "--min-specdec-speedup",
        type=float,
        default=1.2,
        dest="min_specdec_speedup",
        help="minimum speculative-vs-plain tokens/s speedup for the INT4 "
        "draft at 1 session in the serving report (default 1.2); skipped "
        "when the report predates the specdec tier",
    )
    args = ap.parse_args(argv)

    try:
        report = _load(args.report)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read bench report {args.report}: {e}")
        return 1

    speedup = report.get("int4_lut_speedup")
    if not _finite(speedup):
        print(f"FAIL: {args.report} has no finite 'int4_lut_speedup' (got {speedup!r})")
        return 1

    par = report.get("int4_lut_parallel_speedup")
    extend = (report.get("extend") or {}).get("lut_extend_speedup")
    print(f"INT4 GEMV: lut {speedup:.2f}x scalar (floor {args.min_speedup:.2f}x)")
    if _finite(par):
        print(f"INT4 GEMV: lut+row-parallel {par:.2f}x scalar")
    if _finite(extend):
        print(f"1-token forward_extend: lut {extend:.2f}x scalar")

    if speedup < args.min_speedup:
        print(
            f"FAIL: INT4 LUT GEMV speedup {speedup:.2f}x is below the "
            f"{args.min_speedup:.2f}x regression floor"
        )
        return 1
    print("OK: LUT kernels clear the regression floor")

    simd_speedup = report.get("int4_simd_speedup")
    simd_available = report.get("simd_available")
    if simd_speedup is None:
        print("SKIP: report predates the SIMD tier (no 'int4_simd_speedup')")
    elif not simd_available:
        print(
            "SKIP: SIMD not available on this host (AVX2+FMA / NEON absent "
            "or vetoed); SIMD tier is a LUT duplicate and is not gated"
        )
    elif not _finite(simd_speedup):
        print(f"FAIL: {args.report} has non-finite 'int4_simd_speedup' ({simd_speedup!r})")
        return 1
    else:
        print(
            f"INT4 GEMV: simd {simd_speedup:.2f}x scalar "
            f"(floor {args.min_simd:.2f}x)"
        )
        if simd_speedup < args.min_simd:
            print(
                f"FAIL: INT4 SIMD GEMV speedup {simd_speedup:.2f}x is below "
                f"the {args.min_simd:.2f}x regression floor"
            )
            return 1
        print("OK: SIMD kernels clear the regression floor")

    if check_metrics_overhead(report, args.report, args.max_metrics_overhead):
        return 1

    if check_failpoint_overhead(report, args.report, args.max_failpoint_overhead):
        return 1

    if args.serving is not None:
        return check_serving(args.serving, args.min_specdec_speedup)
    return 0


if __name__ == "__main__":
    sys.exit(main())
