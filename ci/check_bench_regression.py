#!/usr/bin/env python3
"""Bench regression gate for the packed kernel engine.

Reads the BENCH_gemv.json report written by
`cargo bench --bench perf_probe -- --gemv-json BENCH_gemv.json`
and fails (exit 1) if the LUT-fused INT4 GEMV kernel is not at least
MIN_SPEEDUP x faster than the scalar unpack-whole-row baseline on the
fixed-iteration smoke run. This is the CI contract behind DESIGN.md §7:
the LUT engine exists to be faster; a regression below the floor means
the fused path has rotted into a slow path and must not merge silently.

Usage: check_bench_regression.py BENCH_gemv.json [--min 1.5]
"""

import argparse
import json
import math
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", help="path to BENCH_gemv.json")
    ap.add_argument(
        "--min",
        type=float,
        default=1.5,
        dest="min_speedup",
        help="minimum INT4 LUT-vs-scalar GEMV speedup (default 1.5)",
    )
    args = ap.parse_args()

    try:
        with open(args.report, encoding="utf-8") as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot read bench report {args.report}: {e}")
        return 1

    speedup = report.get("int4_lut_speedup")
    if not isinstance(speedup, (int, float)) or not math.isfinite(speedup):
        print(f"FAIL: {args.report} has no finite 'int4_lut_speedup' (got {speedup!r})")
        return 1

    par = report.get("int4_lut_parallel_speedup")
    extend = (report.get("extend") or {}).get("lut_extend_speedup")
    print(f"INT4 GEMV: lut {speedup:.2f}x scalar (floor {args.min_speedup:.2f}x)")
    if isinstance(par, (int, float)) and math.isfinite(par):
        print(f"INT4 GEMV: lut+row-parallel {par:.2f}x scalar")
    if isinstance(extend, (int, float)) and math.isfinite(extend):
        print(f"1-token forward_extend: lut {extend:.2f}x scalar")

    if speedup < args.min_speedup:
        print(
            f"FAIL: INT4 LUT GEMV speedup {speedup:.2f}x is below the "
            f"{args.min_speedup:.2f}x regression floor"
        )
        return 1
    print("OK: LUT kernels clear the regression floor")
    return 0


if __name__ == "__main__":
    sys.exit(main())
