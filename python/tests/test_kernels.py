"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/values; every kernel must match its ref within
f32 tolerance for all generated cases. This is the CORE correctness
signal of the compile path — the HLO the rust runtime executes contains
exactly these kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant_matmul import quant_matmul
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels.split_matmul import split_matmul

DIM = st.integers(min_value=1, max_value=40)


def rnd(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def rnd_int8(rng, *shape, lo=-8, hi=8):
    return jnp.asarray(rng.integers(lo, hi, size=shape), jnp.int8)


class TestQuantMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, n=DIM, k=DIM, seed=st.integers(0, 2**31))
    def test_matches_ref(self, m, n, k, seed):
        rng = np.random.default_rng(seed)
        x = rnd(rng, m, k)
        wq = rnd_int8(rng, n, k)
        scale = float(rng.uniform(0.5, 20.0))
        zp = float(rng.integers(-8, 8))
        got = quant_matmul(x, wq, scale, zp)
        want = ref.ref_quant_matmul(x, wq, scale, zp)
        # f32 accumulation order differs between the tiled kernel and the
        # single jnp contraction — tolerance reflects that, not semantics.
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_blocking_boundaries(self):
        # Shapes straddling the block size must tile correctly.
        rng = np.random.default_rng(0)
        for m, n in [(127, 129), (128, 128), (1, 256), (130, 1)]:
            x = rnd(rng, m, 64)
            wq = rnd_int8(rng, n, 64)
            got = quant_matmul(x, wq, 2.0, 1.0)
            want = ref.ref_quant_matmul(x, wq, 2.0, 1.0)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_int8_extremes(self):
        rng = np.random.default_rng(1)
        x = rnd(rng, 4, 8)
        wq = jnp.asarray(np.full((3, 8), -128), jnp.int8)
        got = quant_matmul(x, wq, 1.0, 0.0)
        want = ref.ref_quant_matmul(x, wq, 1.0, 0.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_zero_scale_rejected_by_semantics(self):
        # scale must be nonzero; dequant with scale=1, zp=q gives zeros.
        x = jnp.ones((2, 3), jnp.float32)
        wq = jnp.full((2, 3), 5, jnp.int8)
        out = quant_matmul(x, wq, 1.0, 5.0)
        np.testing.assert_allclose(out, np.zeros((2, 2)), atol=1e-6)


class TestSplitMatmul:
    @settings(max_examples=25, deadline=None)
    @given(
        m=DIM, n=DIM, kd=DIM,
        k=st.integers(1, 4),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, m, n, kd, k, seed):
        rng = np.random.default_rng(seed)
        x = rnd(rng, m, kd)
        planes = rnd_int8(rng, k, n, kd)
        scales = jnp.asarray(rng.uniform(0.5, 30.0, k), jnp.float32)
        zps = jnp.asarray(rng.integers(-8, 8, k), jnp.float32)
        got = split_matmul(x, planes, scales, zps)
        want = ref.ref_split_matmul(x, planes, scales, zps)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_k1_equals_quant_matmul(self):
        rng = np.random.default_rng(2)
        x = rnd(rng, 9, 17)
        wq = rnd_int8(rng, 13, 17)
        a = split_matmul(x, wq[None], jnp.asarray([3.0]), jnp.asarray([-1.0]))
        b = quant_matmul(x, wq, 3.0, -1.0)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_masked_sum_reconstruction(self):
        # The SplitQuantV2 invariant end-to-end: quantize 3 masked planes
        # of a weight matrix; the split matmul must approximate the FP
        # matmul better than single-plane quantization (outlier case).
        rng = np.random.default_rng(3)
        w = rng.normal(0.0, 0.05, size=(24, 16)).astype(np.float32)
        w[0, 0], w[5, 7] = 3.0, -2.5  # outliers
        x = rnd(rng, 8, 16)

        def quantize(vals, lo, hi, bits=4):
            lo, hi = min(lo, 0.0), max(hi, 0.0)
            scale = (2**bits - 1) / (hi - lo)
            zp = -(2 ** (bits - 1)) - round(scale * lo)
            q = np.clip(np.round(scale * vals) + zp, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1)
            return q.astype(np.int8), scale, zp

        # 3 value clusters by simple thresholds (mimic k-means output).
        bounds = [-1.0, 1.0]
        masks = [w <= bounds[0], (w > bounds[0]) & (w <= bounds[1]), w > bounds[1]]
        planes, scales, zps = [], [], []
        for mask in masks:
            vals = np.where(mask, w, 0.0)
            lo = float(vals.min()) if mask.any() else 0.0
            hi = float(vals.max()) if mask.any() else 0.0
            q, s, z = quantize(vals, lo, hi)
            planes.append(q)
            scales.append(s)
            zps.append(z)
        y_split = split_matmul(
            jnp.asarray(x),
            jnp.asarray(np.stack(planes)),
            jnp.asarray(scales, jnp.float32),
            jnp.asarray(zps, jnp.float32),
        )
        qb, sb, zb = quantize(w, float(w.min()), float(w.max()))
        y_base = quant_matmul(jnp.asarray(x), jnp.asarray(qb), sb, zb)
        y_fp = np.asarray(x) @ w.T
        err_split = float(np.mean((np.asarray(y_split) - y_fp) ** 2))
        err_base = float(np.mean((np.asarray(y_base) - y_fp) ** 2))
        assert err_split < err_base * 0.5, (err_split, err_base)


class TestRmsNorm:
    @settings(max_examples=20, deadline=None)
    @given(t=DIM, d=st.integers(2, 64), seed=st.integers(0, 2**31))
    def test_matches_ref(self, t, d, seed):
        rng = np.random.default_rng(seed)
        x = rnd(rng, t, d)
        g = rnd(rng, d)
        np.testing.assert_allclose(
            rmsnorm(x, g), ref.ref_rmsnorm(x, g), rtol=1e-5, atol=1e-5
        )

    def test_unit_gamma_preserves_direction(self):
        rng = np.random.default_rng(4)
        x = rnd(rng, 3, 16)
        y = np.asarray(rmsnorm(x, jnp.ones(16)))
        # Each row is a positive rescaling of the input row.
        for i in range(3):
            ratio = y[i] / np.asarray(x)[i]
            ratio = ratio[np.abs(np.asarray(x)[i]) > 1e-4]
            assert np.allclose(ratio, ratio[0], rtol=1e-4)
            assert ratio[0] > 0

    def test_rows_normalized_independently(self):
        x = jnp.asarray([[1.0, 1.0], [100.0, 100.0]], jnp.float32)
        y = np.asarray(rmsnorm(x, jnp.ones(2)))
        np.testing.assert_allclose(y[0], y[1], rtol=1e-4)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
