"""L2 correctness: model shapes, quantized-forward parity, SQTZ format."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import sqtz
from compile.datagen import FactWorld
from compile.model import (
    Config,
    forward_fp,
    forward_quant,
    init_params,
    lm_loss,
    param_shapes,
    score_fp_last,
)

CFG = Config.test()


def quantize_np(w, bits=8):
    """Paper Eq. 1-3 with the zero-inclusive range (mirror of rust)."""
    lo, hi = min(float(w.min()), 0.0), max(float(w.max()), 0.0)
    if hi == lo:
        return np.zeros_like(w, np.int8), 1.0, 0
    scale = (2**bits - 1) / (hi - lo)
    zp = int(-(2 ** (bits - 1)) - round(scale * lo))
    q = np.clip(np.round(scale * w) + zp, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1)
    return q.astype(np.int8), scale, zp


class TestForward:
    def test_shapes_and_finite(self):
        params = init_params(CFG, 0)
        toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        logits = forward_fp(CFG, params, toks)
        assert logits.shape == (1, 4, CFG.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_causality(self):
        params = init_params(CFG, 1)
        a = forward_fp(CFG, params, jnp.asarray([[5, 6, 7, 8]], jnp.int32))
        b = forward_fp(CFG, params, jnp.asarray([[5, 6, 7, 1]], jnp.int32))
        np.testing.assert_allclose(a[0, :3], b[0, :3], atol=1e-5)
        assert not np.allclose(a[0, 3], b[0, 3], atol=1e-5)

    def test_loss_decreases_under_memorization_gradient(self):
        params = init_params(CFG, 2)
        batch = jnp.asarray([[1, 5, 6, 7, 2]] * 8, jnp.int32)
        import jax

        l0, g = jax.value_and_grad(lambda p: lm_loss(CFG, p, batch))(params)
        params2 = {k: v - 0.1 * g[k] for k, v in params.items()}
        l1 = lm_loss(CFG, params2, batch)
        assert float(l1) < float(l0)

    def test_score_last_matches_full_forward(self):
        params = init_params(CFG, 3)
        toks = jnp.asarray([[1, 2, 3]], jnp.int32)
        full = forward_fp(CFG, params, toks)
        last = score_fp_last(CFG, params, toks)
        np.testing.assert_allclose(last[0], full[0, -1], atol=1e-6)


class TestQuantForwardParity:
    def test_int8_quant_forward_close_to_fp(self):
        """k=1 INT8 quantized forward ≈ FP forward (high resolution)."""
        params = init_params(CFG, 4)
        toks = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
        qargs = {}
        for name, shape in param_shapes(CFG).items():
            if "norm" in name:
                qargs[name] = params[name]
            elif name != "embed.tok":
                q, s, z = quantize_np(np.asarray(params[name]), bits=8)
                qargs[f"{name}.planes"] = jnp.asarray(q[None])
                qargs[f"{name}.scales"] = jnp.asarray([s], jnp.float32)
                qargs[f"{name}.zps"] = jnp.asarray([float(z)], jnp.float32)
        got = forward_quant(CFG, toks, params["embed.tok"], qargs)
        want = score_fp_last(CFG, params, toks)
        np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)

    def test_identity_planes_exactly_match_fp(self):
        """With scale=1, zp=0 and integer weights, quant == fp exactly."""
        params = init_params(CFG, 5)
        # Replace linears with small integer weights.
        for name in list(params):
            if "norm" in name or name == "embed.tok":
                continue
            rng = np.random.default_rng(hash(name) % 2**32)
            w = rng.integers(-3, 4, size=params[name].shape).astype(np.float32)
            params[name] = jnp.asarray(w)
        toks = jnp.asarray([[1, 2, 3]], jnp.int32)
        qargs = {}
        for name, shape in param_shapes(CFG).items():
            if "norm" in name:
                qargs[name] = params[name]
            elif name != "embed.tok":
                qargs[f"{name}.planes"] = jnp.asarray(
                    np.asarray(params[name], np.int8)[None]
                )
                qargs[f"{name}.scales"] = jnp.asarray([1.0], jnp.float32)
                qargs[f"{name}.zps"] = jnp.asarray([0.0], jnp.float32)
        got = forward_quant(CFG, toks, params["embed.tok"], qargs)
        want = score_fp_last(CFG, params, toks)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestSqtz:
    def test_roundtrip(self, tmp_path):
        tensors = {
            "a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.asarray([-8, 0, 7], np.int8),
            "c": np.asarray([1, 2, 255], np.uint8),
        }
        p = str(tmp_path / "x.sqtz")
        sqtz.write_file(p, tensors, {"k": "v"}, {"d_model": 32})
        back, meta, cfg = sqtz.read_file(p)
        assert meta["k"] == "v"
        assert cfg["d_model"] == 32
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])

    def test_rejects_bad_magic(self):
        with pytest.raises(ValueError):
            sqtz.from_bytes(b"XXXX" + b"\0" * 32)

    def test_matches_rust_reader_expectations(self, tmp_path):
        # Byte-level pin: header fields in the exact layout rust parses.
        p = str(tmp_path / "pin.sqtz")
        sqtz.write_file(p, {"t": np.asarray([1.5], np.float32)})
        blob = open(p, "rb").read()
        assert blob[0:4] == b"SQTZ"
        assert int.from_bytes(blob[4:8], "little") == 1
        hlen = int.from_bytes(blob[8:16], "little")
        header = json.loads(blob[16 : 16 + hlen])
        spec = header["tensors"]["t"]
        assert spec["dtype"] == "f32" and spec["shape"] == [1]
        assert spec["offset"] % 16 == 0


class TestDatagen:
    def test_world_deterministic_and_sized(self):
        a, b = FactWorld(), FactWorld()
        assert np.array_equal(a.facts, b.facts)
        assert a.vocab_size == 211  # must match PicoLlamaConfig::eval()

    def test_problem_correctness(self):
        w = FactWorld()
        ps = w.problems(50, 3)
        for p in ps:
            e = p["prompt"][1] - 5
            a = p["prompt"][2] - 5 - w.n_entities
            v = int(w.facts[e, a])
            assert p["options"][p["correct"]] == [w.value_token(v)]
            assert len({tuple(o) for o in p["options"]}) == 4

    def test_corpus_statement_grammar(self):
        w = FactWorld()
        c = w.corpus(1, 0)
        assert c.shape == (w.n_entities * w.n_attrs, 5)
        assert (c[:, 0] == 1).all() and (c[:, 4] == 2).all()
        assert (c[:, 3] >= w.value_token(0)).all()


class TestArtifacts:
    """Validate the emitted artifacts (requires `make artifacts` ran)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART, "manifest.json")),
        reason="artifacts not built",
    )
    def test_manifest_consistency(self):
        with open(os.path.join(self.ART, "manifest.json")) as f:
            m = json.load(f)
        assert m["format"] == "splitquant-artifacts-v1"
        for name, v in m["variants"].items():
            path = os.path.join(self.ART, v["file"])
            assert os.path.exists(path), name
            text = open(path).read()
            assert text.startswith("HloModule"), name
            assert len(v["args"]) >= 4

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART, "picollama_eval.sqtz")),
        reason="checkpoint not trained",
    )
    def test_trained_checkpoint_loads_and_memorized(self):
        tensors, meta, cfg = sqtz.read_file(
            os.path.join(self.ART, "picollama_eval.sqtz")
        )
        assert cfg["vocab"] == 211
        assert float(meta["fact_accuracy"]) > 0.9
        assert tensors["embed.tok"].shape == (211, 128)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
