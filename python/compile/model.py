"""L2: picollama — the Llama-3-family transformer in JAX.

Mirrors rust/src/model exactly (same parameter names, same RoPE pairing,
same GQA layout, weights `[out, in]` applied as `y = x · Wᵀ`), so logits
from the CPU reference forward, this JAX forward, and the PJRT-executed
HLO all agree to f32 tolerance.

Three forward variants:
  * ``forward_fp``    — plain jnp; used for training and the FP export.
  * ``forward_quant`` — every linear is the Pallas ``split_matmul``
    kernel consuming k stacked int8 planes + scales/zero-points
    (k=1 reproduces baseline linear quantization, k=3 is SplitQuantV2).
    RMSNorm runs through the Pallas ``rmsnorm`` kernel.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from .kernels.split_matmul import split_matmul

Params = Dict[str, jax.Array]


class Config:
    """Mirror of rust PicoLlamaConfig (defaults = the eval model)."""

    def __init__(
        self,
        vocab=211,
        d_model=128,
        n_layers=4,
        n_heads=4,
        n_kv_heads=2,
        d_ff=352,
        max_seq=64,
        rope_theta=10_000.0,
        norm_eps=1e-5,
        tie_embeddings=True,
    ):
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.n_kv_heads = n_kv_heads
        self.d_ff = d_ff
        self.max_seq = max_seq
        self.rope_theta = rope_theta
        self.norm_eps = norm_eps
        self.tie_embeddings = tie_embeddings

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    @property
    def kv_dim(self):
        return self.n_kv_heads * self.head_dim

    def to_json(self) -> dict:
        return {
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "n_kv_heads": self.n_kv_heads,
            "d_ff": self.d_ff,
            "max_seq": self.max_seq,
            "rope_theta": self.rope_theta,
            "norm_eps": self.norm_eps,
            "tie_embeddings": self.tie_embeddings,
        }

    @staticmethod
    def test():
        return Config(vocab=96, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                      d_ff=64, max_seq=32)


def param_shapes(cfg: Config) -> Dict[str, tuple]:
    """Canonical inventory (must match rust `param_inventory`)."""
    shapes = {"embed.tok": (cfg.vocab, cfg.d_model)}
    for l in range(cfg.n_layers):
        p = f"layers.{l}"
        shapes[f"{p}.norm_attn"] = (cfg.d_model,)
        shapes[f"{p}.attn.wq"] = (cfg.d_model, cfg.d_model)
        shapes[f"{p}.attn.wk"] = (cfg.kv_dim, cfg.d_model)
        shapes[f"{p}.attn.wv"] = (cfg.kv_dim, cfg.d_model)
        shapes[f"{p}.attn.wo"] = (cfg.d_model, cfg.d_model)
        shapes[f"{p}.norm_mlp"] = (cfg.d_model,)
        shapes[f"{p}.mlp.gate"] = (cfg.d_ff, cfg.d_model)
        shapes[f"{p}.mlp.up"] = (cfg.d_ff, cfg.d_model)
        shapes[f"{p}.mlp.down"] = (cfg.d_model, cfg.d_ff)
    shapes["norm.final"] = (cfg.d_model,)
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.vocab, cfg.d_model)
    return shapes


def init_params(cfg: Config, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        if "norm" in name:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            std = min((2.0 / fan_in) ** 0.5, 0.08)
            params[name] = jnp.asarray(rng.normal(0.0, std, shape), jnp.float32)
    return params


def _rmsnorm_jnp(x, gamma, eps):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gamma


def _rope(x, n_heads: int, head_dim: int, theta: float):
    """x: [B, S, n_heads*head_dim] — rotate (2i, 2i+1) pairs per head.

    Matches rust `forward::rope` exactly.
    """
    b, s, _ = x.shape
    x = x.reshape(b, s, n_heads, head_dim // 2, 2)
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    freq = 1.0 / (theta ** (2.0 * i / head_dim))  # [hd/2]
    t = jnp.arange(s, dtype=jnp.float32)
    ang = t[:, None] * freq[None, :]  # [S, hd/2]
    sin = jnp.sin(ang)[None, :, None, :]
    cos = jnp.cos(ang)[None, :, None, :]
    a, bb = x[..., 0], x[..., 1]
    ra = a * cos - bb * sin
    rb = a * sin + bb * cos
    out = jnp.stack([ra, rb], axis=-1)
    return out.reshape(b, s, n_heads * head_dim)


def _attention(cfg: Config, q, k, v):
    """q: [B,S,d], k/v: [B,S,kv_dim] → [B,S,d]; causal GQA."""
    b, s, _ = q.shape
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    # Expand kv heads to match q heads.
    k = jnp.repeat(k, groups, axis=2)
    v = jnp.repeat(v, groups, axis=2)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v)
    return out.reshape(b, s, cfg.n_heads * hd)


def forward_fp(cfg: Config, params: Params, tokens) -> jax.Array:
    """tokens: int32 [B, S] → logits f32 [B, S, vocab] (plain jnp)."""

    def lin(name, x):
        return x @ params[name].T

    x = params["embed.tok"][tokens]
    for l in range(cfg.n_layers):
        p = f"layers.{l}"
        xn = _rmsnorm_jnp(x, params[f"{p}.norm_attn"], cfg.norm_eps)
        q = _rope(lin(f"{p}.attn.wq", xn), cfg.n_heads, cfg.head_dim, cfg.rope_theta)
        k = _rope(lin(f"{p}.attn.wk", xn), cfg.n_kv_heads, cfg.head_dim, cfg.rope_theta)
        v = lin(f"{p}.attn.wv", xn)
        x = x + lin(f"{p}.attn.wo", _attention(cfg, q, k, v))
        xn = _rmsnorm_jnp(x, params[f"{p}.norm_mlp"], cfg.norm_eps)
        gate = lin(f"{p}.mlp.gate", xn)
        up = lin(f"{p}.mlp.up", xn)
        x = x + lin(f"{p}.mlp.down", jax.nn.silu(gate) * up)
    xn = _rmsnorm_jnp(x, params["norm.final"], cfg.norm_eps)
    head = params["embed.tok"] if cfg.tie_embeddings else params["lm_head"]
    return xn @ head.T


# ---------------------------------------------------------------------------
# Quantized forward (Pallas kernels; k=1 baseline, k=3 SplitQuantV2)
# ---------------------------------------------------------------------------

LINEAR_NAMES = ["attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.gate", "mlp.up", "mlp.down"]


def quant_arg_names(cfg: Config) -> list:
    """Flat ordered argument list of the quantized forward — the manifest
    contract with the rust runtime. For each linear: (planes, scales,
    zps); embedding + norms are f32 args."""
    names = ["tokens", "embed.tok"]
    for l in range(cfg.n_layers):
        p = f"layers.{l}"
        names.append(f"{p}.norm_attn")
        for ln in LINEAR_NAMES[:4]:
            names += [f"{p}.{ln}.planes", f"{p}.{ln}.scales", f"{p}.{ln}.zps"]
        names.append(f"{p}.norm_mlp")
        for ln in LINEAR_NAMES[4:]:
            names += [f"{p}.{ln}.planes", f"{p}.{ln}.scales", f"{p}.{ln}.zps"]
    names.append("norm.final")
    return names


def forward_quant(cfg: Config, tokens, embed, qargs: Dict[str, jax.Array]) -> jax.Array:
    """Quantized forward: every linear is the Pallas split_matmul kernel.

    qargs maps "<layer>.planes" (int8 [k, out, in]), ".scales", ".zps"
    (f32 [k]) for every linear. `embed` is the dequantized embedding
    (f32), which also serves as the tied LM head.
    tokens: int32 [B, S] → logits at the LAST position only: [B, vocab].
    """
    b, s = tokens.shape

    def qlin(name, x):
        bb, ss, din = x.shape
        y = split_matmul(
            x.reshape(bb * ss, din),
            qargs[f"{name}.planes"],
            qargs[f"{name}.scales"],
            qargs[f"{name}.zps"],
        )
        return y.reshape(bb, ss, -1)

    def norm(gamma, x):
        bb, ss, din = x.shape
        return rmsnorm_kernel(x.reshape(bb * ss, din), gamma, eps=cfg.norm_eps).reshape(
            bb, ss, din
        )

    x = embed[tokens]
    for l in range(cfg.n_layers):
        p = f"layers.{l}"
        xn = norm(qargs[f"{p}.norm_attn"], x)
        q = _rope(qlin(f"{p}.attn.wq", xn), cfg.n_heads, cfg.head_dim, cfg.rope_theta)
        k = _rope(qlin(f"{p}.attn.wk", xn), cfg.n_kv_heads, cfg.head_dim, cfg.rope_theta)
        v = qlin(f"{p}.attn.wv", xn)
        x = x + qlin(f"{p}.attn.wo", _attention(cfg, q, k, v))
        xn = norm(qargs[f"{p}.norm_mlp"], x)
        gate = qlin(f"{p}.mlp.gate", xn)
        up = qlin(f"{p}.mlp.up", xn)
        x = x + qlin(f"{p}.mlp.down", jax.nn.silu(gate) * up)
    xn = norm(qargs["norm.final"], x)
    last = xn[:, -1, :]  # [B, d]
    return last @ embed.T  # [B, vocab]


def score_fp_last(cfg: Config, params: Params, tokens) -> jax.Array:
    """FP scoring head: logits at the last position, [B, vocab]."""
    return forward_fp(cfg, params, tokens)[:, -1, :]


# ---------------------------------------------------------------------------
# Training-loss helpers (used by train.py)
# ---------------------------------------------------------------------------


def lm_loss(cfg: Config, params: Params, tokens) -> jax.Array:
    """Next-token cross-entropy over positions 0..S-2 → scalar."""
    logits = forward_fp(cfg, params, tokens)  # [B, S, V]
    targets = tokens[:, 1:]
    logits = logits[:, :-1, :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
