"""Synthetic-ARC data generation — the build-time half of the DESIGN.md
§3 substitution for the paper's ARC Challenge set.

Generates, deterministically from seeds:
  * a fact world: (entity, attribute) -> value over a small symbolic vocab,
  * a training corpus of statements `<bos> e a v <eos>`,
  * the canonical 1165-problem 4-choice eval set (mirroring the ARC set
    size), scored by max continuation likelihood.

Token layout mirrors rust/src/data/mod.rs:
  0 <pad>  1 <bos>  2 <eos>  3 <sep>  4 <?>   then entities, attrs, values.

Run: python -m compile.datagen --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

PAD, BOS, EOS, SEP, QMARK = 0, 1, 2, 3, 4
N_SPECIAL = 5

# Canonical world parameters — must agree with PicoLlamaConfig::eval()
# (vocab = N_SPECIAL + N_ENTITIES + N_ATTRS + N_VALUES = 211).
N_ENTITIES = 120
N_ATTRS = 6
N_VALUES = 80
WORLD_SEED = 2026
N_PROBLEMS = 1165  # = the ARC set prepared for Llama 3.2 (paper §4)
PROBLEM_SEED = 31


class FactWorld:
    def __init__(self, n_entities=N_ENTITIES, n_attrs=N_ATTRS, n_values=N_VALUES, seed=WORLD_SEED):
        self.n_entities = n_entities
        self.n_attrs = n_attrs
        self.n_values = n_values
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.facts = rng.integers(0, n_values, size=(n_entities, n_attrs))

    @property
    def vocab_size(self) -> int:
        return N_SPECIAL + self.n_entities + self.n_attrs + self.n_values

    def entity_token(self, e: int) -> int:
        return N_SPECIAL + e

    def attr_token(self, a: int) -> int:
        return N_SPECIAL + self.n_entities + a

    def value_token(self, v: int) -> int:
        return N_SPECIAL + self.n_entities + self.n_attrs + v

    def statement(self, e: int, a: int) -> list[int]:
        return [
            BOS,
            self.entity_token(e),
            self.attr_token(a),
            self.value_token(int(self.facts[e, a])),
            EOS,
        ]

    def corpus(self, repeats: int, seed: int) -> np.ndarray:
        """All facts stated `repeats` times, shuffled: [n, 5] int32."""
        rows = []
        for _ in range(repeats):
            for e in range(self.n_entities):
                for a in range(self.n_attrs):
                    rows.append(self.statement(e, a))
        arr = np.asarray(rows, dtype=np.int32)
        rng = np.random.default_rng(seed)
        rng.shuffle(arr, axis=0)
        return arr

    def problems(self, n: int, seed: int) -> list[dict]:
        rng = np.random.default_rng(seed)
        out = []
        for _ in range(n):
            e = int(rng.integers(0, self.n_entities))
            a = int(rng.integers(0, self.n_attrs))
            v = int(self.facts[e, a])
            opts = [v]
            while len(opts) < 4:
                d = int(rng.integers(0, self.n_values))
                if d not in opts:
                    opts.append(d)
            opts = [opts[i] for i in rng.permutation(4)]
            out.append(
                {
                    "prompt": [BOS, self.entity_token(e), self.attr_token(a)],
                    "options": [[self.value_token(o)] for o in opts],
                    "correct": opts.index(v),
                }
            )
        return out


def write_problems(path: str, problems: list[dict], vocab_size: int) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(
            {"format": "synthetic-arc-v1", "vocab_size": vocab_size, "problems": problems},
            f,
            indent=1,
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--repeats", type=int, default=40)
    ap.add_argument("--corpus-seed", type=int, default=7)
    args = ap.parse_args()

    world = FactWorld()
    os.makedirs(args.out, exist_ok=True)

    corpus = world.corpus(args.repeats, args.corpus_seed)
    np.save(os.path.join(args.out, "corpus.npy"), corpus)

    problems = world.problems(N_PROBLEMS, PROBLEM_SEED)
    write_problems(os.path.join(args.out, "eval_problems.json"), problems, world.vocab_size)

    # Calibration split (for GPTQ-lite / activation-split experiments):
    # held-out statements, NOT the eval problems.
    calib = world.corpus(1, 12345)[:256]
    np.save(os.path.join(args.out, "calibration.npy"), calib)

    print(
        f"world: {world.n_entities}x{world.n_attrs} facts, vocab={world.vocab_size}; "
        f"corpus={corpus.shape}, problems={len(problems)}, calib={calib.shape}"
    )


if __name__ == "__main__":
    main()
