"""AOT lowering: JAX → HLO text → artifacts/ for the rust PJRT runtime.

Variants exported (see DESIGN.md §6):
  * ``score_fp``        — FP32 scoring head: (tokens[B,3] i32, params…) →
                          last-position logits [B, vocab].
  * ``score_quant_k1``  — baseline linear quantization: every linear is
                          one int8 plane through the Pallas split_matmul
                          kernel (k=1).
  * ``score_quant_k3``  — SplitQuantV2: k=3 planes per linear.
  * ``linear_micro_k3`` — standalone split_matmul kernel (runtime micro
                          benches of the L1 hot-spot).

Interchange is HLO **text** (not serialized HloModuleProto): jax ≥0.5
emits 64-bit instruction ids that the image's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
Every variant is described in ``artifacts/manifest.json`` (file, ordered
argument names/dtypes/shapes, output shape) — the contract the rust
runtime loads.

Run: python -m compile.aot --out ../artifacts [--batch 32]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import Config, forward_quant, param_shapes, score_fp_last

PROMPT_LEN = 3  # synthetic-arc prompts are <bos> e a


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def arg_json(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def export_score_fp(cfg: Config, batch: int):
    shapes = param_shapes(cfg)
    names = sorted(shapes)  # canonical: sorted param names after tokens

    def fn(tokens, *flat):
        params = dict(zip(names, flat))
        return (score_fp_last(cfg, params, tokens),)

    args = [spec((batch, PROMPT_LEN), jnp.int32)] + [
        spec(shapes[n], jnp.float32) for n in names
    ]
    lowered = jax.jit(fn).lower(*args)
    arg_manifest = [arg_json("tokens", (batch, PROMPT_LEN), "i32")] + [
        arg_json(n, shapes[n], "f32") for n in names
    ]
    return to_hlo_text(lowered), arg_manifest, [batch, cfg.vocab]


def quant_flat_args(cfg: Config, k: int):
    """Ordered (name, shape, dtype) for the quantized variant."""
    shapes = param_shapes(cfg)
    out = [("tokens", (0, PROMPT_LEN), "i32"), ("embed.tok", shapes["embed.tok"], "f32")]
    lin_names = []
    for l in range(cfg.n_layers):
        p = f"layers.{l}"
        out.append((f"{p}.norm_attn", shapes[f"{p}.norm_attn"], "f32"))
        for ln in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"]:
            lin_names.append(f"{p}.{ln}")
            o, i = shapes[f"{p}.{ln}"]
            out += [
                (f"{p}.{ln}.planes", (k, o, i), "i8"),
                (f"{p}.{ln}.scales", (k,), "f32"),
                (f"{p}.{ln}.zps", (k,), "f32"),
            ]
        out.append((f"{p}.norm_mlp", shapes[f"{p}.norm_mlp"], "f32"))
        for ln in ["mlp.gate", "mlp.up", "mlp.down"]:
            lin_names.append(f"{p}.{ln}")
            o, i = shapes[f"{p}.{ln}"]
            out += [
                (f"{p}.{ln}.planes", (k, o, i), "i8"),
                (f"{p}.{ln}.scales", (k,), "f32"),
                (f"{p}.{ln}.zps", (k,), "f32"),
            ]
    out.append(("norm.final", shapes["norm.final"], "f32"))
    return out


def export_score_quant(cfg: Config, batch: int, k: int):
    flat = quant_flat_args(cfg, k)
    arg_names = [f[0] for f in flat]

    def fn(*args):
        d = dict(zip(arg_names, args))
        qargs = {n: a for n, a in d.items() if n not in ("tokens", "embed.tok")}
        return (forward_quant(cfg, d["tokens"], d["embed.tok"], qargs),)

    jax_args = []
    manifest = []
    for name, shape, dtype in flat:
        shape = (batch, PROMPT_LEN) if name == "tokens" else shape
        jd = {"i32": jnp.int32, "f32": jnp.float32, "i8": jnp.int8}[dtype]
        jax_args.append(spec(shape, jd))
        manifest.append(arg_json(name, shape, dtype))
    lowered = jax.jit(fn).lower(*jax_args)
    return to_hlo_text(lowered), manifest, [batch, cfg.vocab]


def export_linear_micro(k: int, m: int = 128, n: int = 128, kd: int = 128):
    from .kernels.split_matmul import split_matmul

    def fn(x, planes, scales, zps):
        return (split_matmul(x, planes, scales, zps),)

    args = [
        spec((m, kd), jnp.float32),
        spec((k, n, kd), jnp.int8),
        spec((k,), jnp.float32),
        spec((k,), jnp.float32),
    ]
    lowered = jax.jit(fn).lower(*args)
    manifest = [
        arg_json("x", (m, kd), "f32"),
        arg_json("planes", (k, n, kd), "i8"),
        arg_json("scales", (k,), "f32"),
        arg_json("zps", (k,), "f32"),
    ]
    return to_hlo_text(lowered), manifest, [m, n]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = Config()  # eval config
    variants = {}

    def emit(name, result):
        hlo, arg_manifest, out_shape = result
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(hlo)
        variants[name] = {
            "file": fname,
            "args": arg_manifest,
            "out_shape": out_shape,
            "out_dtype": "f32",
        }
        print(f"{name}: {len(hlo)/1e6:.2f} MB HLO, {len(arg_manifest)} args")

    emit("score_fp", export_score_fp(cfg, args.batch))
    emit("score_quant_k1", export_score_quant(cfg, args.batch, k=1))
    emit("score_quant_k3", export_score_quant(cfg, args.batch, k=3))
    emit("linear_micro_k3", export_linear_micro(k=3))

    manifest = {
        "format": "splitquant-artifacts-v1",
        "batch": args.batch,
        "prompt_len": PROMPT_LEN,
        "config": cfg.to_json(),
        "variants": variants,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(variants)} variants")


if __name__ == "__main__":
    main()
