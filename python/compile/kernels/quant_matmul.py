"""Pallas kernel: fused dequantize–matmul (the inference hot-spot).

Computes y[M, N] = x[M, K] · dequant(wq[N, K])ᵀ with per-tensor
scale/zero-point, dequantizing INT8 levels *inside* the kernel so the
f32 weight plane never materializes in HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles M×N into
(BM, BN) output blocks; each step streams a (BN, K) weight stripe and a
(BM, K) activation stripe HBM→VMEM via BlockSpec, dequantizes in VMEM
(VPU elementwise), and feeds the MXU with an f32/bf16 contraction.
VMEM budget per step ≈ BM·K·4 + BN·K·(1+4) + BM·BN·4 bytes — at the
default BM=BN=128 and K≤2048 that is ≈1.3 MiB + 2.5 MiB + 64 KiB, well
under the ~16 MiB VMEM of a modern TPU core. `interpret=True` is
mandatory here: the CPU PJRT plugin cannot execute Mosaic custom-calls;
the compiled-for-TPU schedule is expressed by the same BlockSpecs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wq_ref, scale_ref, zp_ref, o_ref):
    x = x_ref[...]                       # (BM, K) f32, VMEM
    wq = wq_ref[...]                     # (BN, K) i8,  VMEM
    scale = scale_ref[0]
    zp = zp_ref[0]
    w = (wq.astype(jnp.float32) - zp) / scale
    o_ref[...] = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def quant_matmul(x, wq, scale, zero_point, *, block_m: int = 128, block_n: int = 128):
    """y[M, N] = x[M, K] · dequant(wq[N, K])ᵀ.

    x: f32 [M, K]; wq: int8 [N, K]; scale, zero_point: f32 scalars
    (passed as shape-(1,) arrays to keep them kernel operands).
    """
    m, k = x.shape
    n, k2 = wq.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    zero_point = jnp.asarray(zero_point, jnp.float32).reshape(1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, wq, scale, zero_point)
