"""Pallas kernel: RMSNorm over the last axis.

y[t, :] = x[t, :] · γ / sqrt(mean(x[t,:]²) + eps)

Row-blocked: each grid step normalizes a (BT, D) stripe fully in VMEM
(one VPU reduction + broadcast multiply; no MXU work). D is the model
width (≤ a few thousand), so a stripe is tens of KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(ms + eps)) * g_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "block_t"))
def rmsnorm(x, gamma, *, eps: float = 1e-5, block_t: int = 128):
    """x: f32 [T, D]; gamma: f32 [D]."""
    t, d = x.shape
    bt = min(block_t, t)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(pl.cdiv(t, bt),),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, gamma)
