"""Pure-jnp oracles for every Pallas kernel — the correctness contract.

Each `ref_*` function defines the semantics its kernel must match
(allclose at f32 tolerance). pytest + hypothesis sweep shapes/values in
python/tests/test_kernels.py.

Dequantization convention (matches rust `quant::QuantParams`):
    x̂ = (q − zero_point) / scale
"""

from __future__ import annotations

import jax.numpy as jnp


def dequant(q, scale, zero_point):
    """De-quantize integer levels (any int dtype) to f32."""
    return (q.astype(jnp.float32) - jnp.float32(zero_point)) / jnp.float32(scale)


def ref_quant_matmul(x, wq, scale, zero_point):
    """y[M, N] = x[M, K] · dequant(wq[N, K])ᵀ   (per-tensor scale/zp)."""
    w = dequant(wq, scale, zero_point)
    return x @ w.T


def ref_split_matmul(x, planes, scales, zero_points):
    """SplitQuantV2 split-layer matmul.

    y[M, N] = Σ_j  x[M, K] · dequant(planes[j], scales[j], zps[j])ᵀ

    planes: int8 [k, N, K]; scales/zero_points: f32 [k].
    """
    y = jnp.zeros((x.shape[0], planes.shape[1]), dtype=jnp.float32)
    for j in range(planes.shape[0]):
        w = (planes[j].astype(jnp.float32) - zero_points[j]) / scales[j]
        y = y + x @ w.T
    return y


def ref_rmsnorm(x, gamma, eps=1e-5):
    """RMSNorm over the last axis: x·γ / sqrt(mean(x²)+eps)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gamma
