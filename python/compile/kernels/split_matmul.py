"""Pallas kernel: SplitQuantV2 split-layer matmul.

Computes the masked-sum split layer in one fused kernel:

    y[M, N] = Σ_{j<k}  x[M, K] · dequant(planes[j][N, K], s_j, z_j)ᵀ

The k (=3) planes are a stacked int8 tensor [k, N, K]. Fusing the sum
matters: the three planes share the same activation stripe, so the
kernel reads x once per output block instead of three times, and the
accumulator stays in VMEM/registers across planes (on TPU: three
back-to-back MXU contractions into one accumulator; the per-plane
dequant is VPU work overlapped with the MXU).

VMEM per step ≈ BM·K·4 (x) + k·BN·K·1 (int8 planes) + BM·BN·4 (acc):
at BM=BN=128, K=2048, k=3 → 1.0 MiB + 0.75 MiB + 64 KiB. The int8
planes are ~4× cheaper to stream than one dequantized f32 plane — the
bandwidth win that makes the 3-plane structure affordable at inference.
`interpret=True` for CPU-PJRT executability (see quant_matmul.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, planes_ref, scales_ref, zps_ref, o_ref, *, k: int):
    x = x_ref[...]  # (BM, K) f32
    acc = jnp.zeros((x.shape[0], planes_ref.shape[1]), jnp.float32)
    for j in range(k):  # k is static — unrolled into 3 MXU passes
        w = (planes_ref[j].astype(jnp.float32) - zps_ref[j]) / scales_ref[j]
        acc = acc + jax.lax.dot_general(
            x,
            w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def split_matmul(x, planes, scales, zero_points, *, block_m: int = 128, block_n: int = 128):
    """y[M, N] = Σ_j x · dequant(planes[j])ᵀ.

    x: f32 [M, K]; planes: int8 [k, N, K]; scales, zero_points: f32 [k].
    """
    m, kdim = x.shape
    nk, n, k2 = planes.shape
    assert kdim == k2, f"inner dims {kdim} vs {k2}"
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    scales = jnp.asarray(scales, jnp.float32).reshape(nk)
    zero_points = jnp.asarray(zero_points, jnp.float32).reshape(nk)
    return pl.pallas_call(
        functools.partial(_kernel, k=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kdim), lambda i, j: (i, 0)),
            pl.BlockSpec((nk, bn, kdim), lambda i, j: (0, j, 0)),
            pl.BlockSpec((nk,), lambda i, j: (0,)),
            pl.BlockSpec((nk,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, planes, scales, zero_points)
