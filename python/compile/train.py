"""Build-time training of the evaluation checkpoint.

Trains picollama (eval config) on the synthetic-arc fact corpus with a
hand-rolled Adam (+ linear warmup, cosine decay) until the facts are
memorized, then writes the checkpoint in SQTZ format for the rust
pipeline. Runs ONCE at `make artifacts`; never on the request path.

Run: python -m compile.train --out ../artifacts [--steps N]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import sqtz
from .datagen import FactWorld
from .model import Config, init_params, lm_loss, param_shapes


def adam_init(params):
    return {
        "m": {k: jnp.zeros_like(v) for k, v in params.items()},
        "v": {k: jnp.zeros_like(v) for k, v in params.items()},
        "t": jnp.zeros((), jnp.int32),
    }


def make_update(cfg: Config, base_lr: float, warmup: int, total: int):
    b1, b2, eps = 0.9, 0.95, 1e-8

    def lr_at(t):
        t = t.astype(jnp.float32)
        warm = jnp.minimum(t / warmup, 1.0)
        prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * (0.1 + 0.9 * cos)

    @jax.jit
    def update(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        t = opt["t"] + 1
        lr = lr_at(t)
        new_m, new_v, new_p = {}, {}, {}
        for k, g in grads.items():
            m = b1 * opt["m"][k] + (1 - b1) * g
            v = b2 * opt["v"][k] + (1 - b2) * jnp.square(g)
            mhat = m / (1 - b1 ** t.astype(jnp.float32))
            vhat = v / (1 - b2 ** t.astype(jnp.float32))
            new_m[k], new_v[k] = m, v
            new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, {"m": new_m, "v": new_v, "t": t}, loss

    return update


def fact_accuracy(cfg: Config, params, world: FactWorld, n_check: int = 400) -> float:
    """Fraction of facts whose value token is argmax after `<bos> e a`."""
    from .model import score_fp_last

    rng = np.random.default_rng(99)
    prompts, correct = [], []
    for _ in range(n_check):
        e = int(rng.integers(0, world.n_entities))
        a = int(rng.integers(0, world.n_attrs))
        prompts.append([1, world.entity_token(e), world.attr_token(a)])
        correct.append(world.value_token(int(world.facts[e, a])))
    logits = score_fp_last(cfg, params, jnp.asarray(prompts, jnp.int32))
    # Restrict argmax to value tokens (the scoring harness compares only
    # the 4 option tokens; full-vocab argmax is a stricter check).
    pred = jnp.argmax(logits, axis=-1)
    return float(jnp.mean(pred == jnp.asarray(correct)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=700)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=17)
    args = ap.parse_args()

    cfg = Config()  # eval config
    world = FactWorld()
    assert world.vocab_size == cfg.vocab, (world.vocab_size, cfg.vocab)

    corpus_path = os.path.join(args.out, "corpus.npy")
    corpus = np.load(corpus_path)
    print(f"corpus {corpus.shape}, vocab {cfg.vocab}")

    params = init_params(cfg, args.seed)
    opt = adam_init(params)
    update = make_update(cfg, args.lr, warmup=50, total=args.steps)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    loss_log = []
    loss = float("nan")
    for step in range(args.steps):
        idx = rng.integers(0, corpus.shape[0], size=args.batch)
        batch = jnp.asarray(corpus[idx], jnp.int32)
        params, opt, loss = update(params, opt, batch)
        if step % 50 == 0 or step == args.steps - 1:
            loss = float(loss)
            loss_log.append({"step": step, "loss": loss})
            print(f"step {step:4d}  loss {loss:.4f}  ({time.time()-t0:.0f}s)")

    acc = fact_accuracy(cfg, params, world)
    print(f"fact accuracy (full-vocab argmax): {acc*100:.2f}%")

    tensors = {k: np.asarray(v, np.float32) for k, v in params.items()}
    # Shape sanity against the inventory.
    for name, shape in param_shapes(cfg).items():
        assert tensors[name].shape == shape, name
    meta = {
        "trained_steps": str(args.steps),
        "final_loss": f"{float(loss):.6f}",
        "fact_accuracy": f"{acc:.4f}",
        "seed": str(args.seed),
    }
    out_path = os.path.join(args.out, "picollama_eval.sqtz")
    sqtz.write_file(out_path, tensors, meta, cfg.to_json())
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump({"loss": loss_log, "fact_accuracy": acc}, f, indent=1)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
