"""SQTZ container — python mirror of rust/src/io/mod.rs.

Layout (little-endian):
    0   4   magic  b"SQTZ"
    4   4   u32    version (1)
    8   8   u64    header length H
    16  H   JSON header
    16+H ...       payload (tensor data at 16-byte-aligned offsets)

Header: {"meta": {str: str}, "config": {...}?, "tensors":
         {name: {"dtype": "f32|i8|u8|i32", "shape": [...],
                 "offset": int, "nbytes": int}}}
"""

from __future__ import annotations

import json
import os
import struct
from typing import Any, Dict, Optional, Tuple

import numpy as np

MAGIC = b"SQTZ"
VERSION = 1
ALIGN = 16

_DTYPES = {
    "f32": np.dtype("<f4"),
    "i8": np.dtype("i1"),
    "u8": np.dtype("u1"),
    "i32": np.dtype("<i4"),
}
_NAMES = {v: k for k, v in _DTYPES.items()}


def _dtype_name(arr: np.ndarray) -> str:
    d = arr.dtype
    if d == np.float32:
        return "f32"
    if d == np.int8:
        return "i8"
    if d == np.uint8:
        return "u8"
    if d == np.int32:
        return "i32"
    raise ValueError(f"unsupported dtype {d} (use f32/i8/u8/i32)")


def to_bytes(
    tensors: Dict[str, np.ndarray],
    meta: Optional[Dict[str, str]] = None,
    config: Optional[Dict[str, Any]] = None,
) -> bytes:
    """Serialize named arrays to SQTZ bytes."""
    payload = bytearray()
    tensor_specs = {}
    for name, arr in tensors.items():
        dname = _dtype_name(arr)
        raw = np.ascontiguousarray(arr).tobytes()
        while len(payload) % ALIGN != 0:
            payload.append(0)
        offset = len(payload)
        payload.extend(raw)
        tensor_specs[name] = {
            "dtype": dname,
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
        }
    header: Dict[str, Any] = {"meta": dict(meta or {}), "tensors": tensor_specs}
    if config is not None:
        header["config"] = config
    hbytes = json.dumps(header, sort_keys=True).encode("utf-8")
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", VERSION)
    out += struct.pack("<Q", len(hbytes))
    out += hbytes
    out += payload
    return bytes(out)


def from_bytes(data: bytes) -> Tuple[Dict[str, np.ndarray], Dict[str, str], Optional[dict]]:
    """Parse SQTZ bytes → (tensors, meta, config)."""
    if len(data) < 16 or data[0:4] != MAGIC:
        raise ValueError("not an SQTZ file (bad magic)")
    (version,) = struct.unpack("<I", data[4:8])
    if version != VERSION:
        raise ValueError(f"unsupported SQTZ version {version}")
    (hlen,) = struct.unpack("<Q", data[8:16])
    if len(data) < 16 + hlen:
        raise ValueError("truncated header")
    header = json.loads(data[16 : 16 + hlen].decode("utf-8"))
    payload = data[16 + hlen :]
    tensors = {}
    for name, spec in header["tensors"].items():
        dt = _DTYPES[spec["dtype"]]
        off, nb = spec["offset"], spec["nbytes"]
        if off + nb > len(payload):
            raise ValueError(f"tensor '{name}' exceeds payload")
        flat = np.frombuffer(payload[off : off + nb], dtype=dt)
        shape = spec["shape"]
        if spec["dtype"] == "u8":
            # Packed planes: free-form byte length; keep flat unless the
            # shape's element count matches exactly.
            if int(np.prod(shape)) == flat.size:
                flat = flat.reshape(shape)
        else:
            flat = flat.reshape(shape)
        tensors[name] = flat.copy()
    return tensors, dict(header.get("meta", {})), header.get("config")


def write_file(
    path: str,
    tensors: Dict[str, np.ndarray],
    meta: Optional[Dict[str, str]] = None,
    config: Optional[Dict[str, Any]] = None,
) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    blob = to_bytes(tensors, meta, config)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)


def read_file(path: str):
    with open(path, "rb") as f:
        return from_bytes(f.read())
