//! Ablation (paper §5): cluster count k and split strategy.
//!
//! Sweeps k ∈ {2, 3, 4} and dynamic-k, plus the row-wise strategy, over
//! the trained checkpoint at INT4, reporting the accuracy/model-size
//! trade-off the paper's §5 discusses (k=2 shrinks the model at some
//! accuracy cost; dynamic-k adapts per layer).
//!
//! Run: cargo run --release --example ablation_clusters

use anyhow::Result;
use splitquant::coordinator::{Arm, Coordinator, PipelineSpec};
use splitquant::model::quantized::Method;
use splitquant::quant::Bits;
use splitquant::split::{DynamicK, SplitConfig, Strategy};
use splitquant::util::fmt::{human_bytes, Table};
use splitquant::util::timer::format_duration;

fn main() -> Result<()> {
    let spec = PipelineSpec::new(
        "artifacts/picollama_eval.sqtz",
        "artifacts/eval_problems.json",
    );
    let coord = Coordinator::new();
    let ck = coord.load_model(&spec)?;
    let problems = coord.load_problems(&spec)?;
    let fp = coord.evaluate_fp(&ck, &problems, false)?;
    println!("FP32 reference: {}", fp.accuracy_pct());

    let mut configs: Vec<(String, Method)> = vec![
        ("baseline (no split)".into(), Method::Baseline),
    ];
    for k in [2usize, 3, 4] {
        configs.push((
            format!("masked-sum k={k}"),
            Method::SplitQuant(SplitConfig::with_k(k)),
        ));
    }
    configs.push((
        "dynamic-k (elbow, k≤4)".into(),
        Method::SplitQuant(SplitConfig {
            dynamic_k: Some(DynamicK::default()),
            ..Default::default()
        }),
    ));
    configs.push((
        "row-wise k=3".into(),
        Method::SplitQuant(SplitConfig {
            strategy: Strategy::RowWise,
            ..Default::default()
        }),
    ));
    configs.push(("ocs ε=0.05".into(), Method::Ocs { expand_ratio: 0.05 }));

    let mut table = Table::new(&["config", "accuracy", "d vs FP", "packed", "quantize"]);
    for (label, method) in configs {
        let arm = Arm {
            bits: Bits::Int4,
            method,
        };
        let res = coord.run_arm(&ck, &arm, &problems, &spec)?;
        table.row(&[
            label,
            res.report.accuracy_pct(),
            format!("{:+.2}%p", (res.report.accuracy - fp.accuracy) * 100.0),
            human_bytes(res.packed_bytes),
            format_duration(res.quantize_time),
        ]);
    }
    println!("\nINT4 ablation over split configurations:\n{}", table.render());
    println!("expected shape: k=3 ≈ k=4 > k=2 > row-wise/ocs > baseline;");
    println!("size: k planes ≈ k/8 of FP32 for the linear layers (§5).");
    Ok(())
}
