//! END-TO-END driver (DESIGN.md E1): the full system on a real workload.
//!
//! Loads the *trained* picollama checkpoint (produced by `make artifacts`
//! → python training at build time), applies the documented outlier
//! amplification to recreate the LLM weight regime, then runs the
//! complete Table-1 grid — Original + INT{8,4,2} × {baseline,
//! SplitQuantV2} — through BOTH evaluation paths:
//!
//!   * the CPU reference forward, and
//!   * the PJRT runtime executing the AOT-lowered HLO (Pallas kernels
//!     inside), proving all three layers compose.
//!
//! Prints the Table-1 analogue and the paper-vs-measured deltas recorded
//! in EXPERIMENTS.md.
//!
//! Run: cargo run --release --example e2e_table1
//!      (requires `make artifacts` to have produced artifacts/)

use anyhow::Result;
use splitquant::coordinator::{Coordinator, PipelineSpec};
use splitquant::runtime::EngineKind;
use splitquant::split::SplitConfig;
use splitquant::util::fmt::{human_bytes, Table};
use splitquant::util::timer::format_duration;

fn main() -> Result<()> {
    let mut spec = PipelineSpec::new(
        "artifacts/picollama_eval.sqtz",
        "artifacts/eval_problems.json",
    );
    spec.amplify = Some((0.003, 4.0));

    // CPU-reference coordinator + PJRT coordinator over the same model.
    let coord = Coordinator::with_engine("artifacts", None)?;
    let ck = coord.load_model(&spec)?;
    let problems = coord.load_problems(&spec)?;
    println!(
        "model: {} params, {} problems, PJRT platform: {}",
        splitquant::util::fmt::human_count(splitquant::model::n_params(&ck.config) as u64),
        problems.len(),
        coord.engine().map(|e| e.platform()).unwrap_or_default()
    );

    let fp_cpu = coord.evaluate_fp(&ck, &problems, false)?;
    let fp_pjrt = coord.evaluate_fp(&ck, &problems, true)?;
    println!(
        "\nFP32: CPU {} | PJRT {}  (paths must agree)",
        fp_cpu.accuracy_pct(),
        fp_pjrt.accuracy_pct()
    );
    assert!(
        (fp_cpu.accuracy - fp_pjrt.accuracy).abs() < 0.02,
        "CPU and PJRT scoring disagree"
    );

    let mut table = Table::new(&[
        "arm",
        "acc (CPU)",
        "acc (PJRT)",
        "d vs FP",
        "quantize",
        "packed",
    ]);
    table.row(&[
        "Original FP32".into(),
        fp_cpu.accuracy_pct(),
        fp_pjrt.accuracy_pct(),
        "-".into(),
        "-".into(),
        human_bytes(ck.fp32_bytes()),
    ]);

    for arm in Coordinator::table1_arms(&SplitConfig::default()) {
        let (qm, qtime) = coord.quantize_arm(&ck, &arm)?;
        let cpu = coord.evaluate_qm(&qm, &problems, false, EngineKind::Reference)?;
        let pjrt = coord.evaluate_qm(&qm, &problems, true, EngineKind::Reference)?;
        assert!(
            (cpu.accuracy - pjrt.accuracy).abs() < 0.02,
            "{}: CPU {} vs PJRT {}",
            arm.label(),
            cpu.accuracy_pct(),
            pjrt.accuracy_pct()
        );
        table.row(&[
            arm.label(),
            cpu.accuracy_pct(),
            pjrt.accuracy_pct(),
            format!("{:+.2}%p", (cpu.accuracy - fp_cpu.accuracy) * 100.0),
            format_duration(qtime),
            human_bytes(qm.packed_bytes()),
        ]);
    }
    println!("\n{}", table.render());

    println!("paper shape check:");
    println!("  INT8 ≈ FP for both arms          (paper: 57.85% vs 57.94%)");
    println!("  INT4 baseline degrades           (paper: 45.92%)");
    println!("  INT4+SplitQuantV2 recovers to FP (paper: 57.68%, +11.76%p)");
    println!("  INT2 both arms collapse          (paper: 0%)");
    println!("\nstage profile:\n{}", coord.profiler.report());
    Ok(())
}
