//! Edge-deployment scenario: pack → export → load-back → serve.
//!
//! The paper's target user: an NPU/edge device that receives a packed
//! SplitQuantV2 model and serves requests without any Python or GPU.
//! This driver exercises the full deployment loop:
//!
//!   1. quantize the trained checkpoint with SplitQuantV2 (INT4, k=3),
//!   2. export the packed container (what would be flashed to a device),
//!   3. load it back (simulating the device side),
//!   4. start the batched scoring server over the PJRT runtime,
//!   5. fire MCQ requests and report accuracy, latency and throughput,
//!   6. stream generations on the packed engine (paged KV arena),
//!      then again speculatively with an INT2 draft proposing tokens
//!      the INT4 target verifies (bit-identical output, speed only),
//!   7. dump the deployment's own telemetry — the final
//!      [`MetricsSnapshot`] with TTFT percentiles, decoded tokens/s and
//!      the arena's occupancy high-water mark (the same registry
//!      `serve --metrics-addr` exposes live on `/metrics`).
//!
//! Run: cargo run --release --example edge_deploy
//!
//! [`MetricsSnapshot`]: splitquant::obs::MetricsSnapshot

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use splitquant::coordinator::server::{Backend, GenerateRequest, Server, ServerConfig};
use splitquant::io::qmodel::{load_qmodel, save_qmodel};
use splitquant::io::checkpoint::load_checkpoint;
use splitquant::model::packed::PackedModel;
use splitquant::model::quantized::{quantize_model, Method};
use splitquant::obs;
use splitquant::quant::Bits;
use splitquant::runtime::scoring;
use splitquant::split::SplitConfig;
use splitquant::util::fmt::human_bytes;
use splitquant::util::stats::Summary;
use splitquant::util::timer::format_duration;

fn main() -> Result<()> {
    // Telemetry on for the whole deployment loop: every serving-side
    // series below lands in the global registry and comes back out of
    // the final snapshot.
    obs::set_enabled(true);

    // 1. Quantize on the "build host".
    let mut ck = load_checkpoint("artifacts/picollama_eval.sqtz")?;
    ck.amplify_outliers(0.003, 4.0, 7);
    let (problems, _) = splitquant::data::load_problems("artifacts/eval_problems.json")?;
    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))?;

    // 2. Export the deployable container.
    let packed_path = std::env::temp_dir().join("picollama_int4_sqv2.sqtz");
    save_qmodel(&packed_path, &qm)?;
    let disk = std::fs::metadata(&packed_path)?.len();
    println!(
        "exported {} ({} on disk, {} logical, FP32 was {})",
        packed_path.display(),
        human_bytes(disk),
        human_bytes(qm.packed_bytes()),
        human_bytes(ck.fp32_bytes())
    );

    // 3. "Device side": load the container back.
    let device_qm = load_qmodel(&packed_path)?;
    println!(
        "device loaded: {} {} with {} linear layers",
        device_qm.bits.name(),
        device_qm.method_name,
        device_qm.linears.len()
    );

    // 4. Start the batched scoring server (PJRT engine inside).
    let weights = scoring::quant_args(&device_qm, 3)?;
    let server = Server::start(
        Backend::Pjrt {
            artifacts_dir: PathBuf::from("artifacts"),
            weight_args: weights,
        },
        ServerConfig::default(),
    )?;

    // 5. Fire requests: a burst (tests batching) then a trickle (tests
    //    latency under low load).
    let n_burst = 256.min(problems.len());
    let t0 = Instant::now();
    let pending: Vec<_> = problems[..n_burst]
        .iter()
        .map(|p| server.submit(p.clone()))
        .collect();
    let mut correct = 0;
    let mut lat_ms = Vec::new();
    let mut batches = Vec::new();
    for rx in pending {
        let resp = rx.recv()??;
        correct += resp.result.is_correct() as usize;
        lat_ms.push(resp.latency().as_secs_f64() * 1e3);
        batches.push(resp.batch_size as f64);
    }
    let burst_wall = t0.elapsed();

    let mut trickle_lat = Vec::new();
    for p in problems[n_burst..n_burst + 20.min(problems.len() - n_burst)].iter() {
        let t = Instant::now();
        let resp = server.score(p.clone())?;
        trickle_lat.push(t.elapsed().as_secs_f64() * 1e3);
        correct += resp.result.is_correct() as usize;
    }

    let s = Summary::of(&lat_ms);
    let ts = Summary::of(&trickle_lat);
    println!("\n-- burst ({n_burst} requests) --");
    println!(
        "wall {}  throughput {:.1} req/s  mean batch {:.1}",
        format_duration(burst_wall),
        n_burst as f64 / burst_wall.as_secs_f64(),
        Summary::of(&batches).mean
    );
    println!("queue latency p50 {:.1}ms  p95 {:.1}ms  max {:.1}ms", s.median, s.p95, s.max);
    println!("\n-- trickle (20 sequential requests) --");
    println!("end-to-end latency p50 {:.1}ms  p95 {:.1}ms", ts.median, ts.p95);
    println!(
        "\naccuracy over all served: {:.2}%",
        100.0 * correct as f64 / (n_burst + trickle_lat.len()) as f64
    );

    // 6. Streaming generation on the packed engine: the same container
    //    served with no PJRT artifacts, exercising the paged KV arena.
    let pm = PackedModel::from_qmodel(&device_qm)?;
    let gen_server = Server::start(Backend::Packed(Box::new(pm)), ServerConfig::default())?;
    let n_gen = 32.min(problems.len());
    let streams: Vec<_> = problems[..n_gen]
        .iter()
        .map(|p| {
            gen_server.submit_generate(GenerateRequest {
                prompt: p.prompt.clone(),
                max_tokens: 8,
                deadline: None,
            })
        })
        .collect::<Result<_>>()?;
    let mut gen_tokens = 0usize;
    for s in streams {
        gen_tokens += s.wait()?.tokens.len();
    }
    println!("\n-- generation ({n_gen} streams, packed engine) --");
    println!(
        "decoded {gen_tokens} tokens; kv blocks in use after drain: {}",
        gen_server.kv_blocks_in_use()
    );

    // 6b. Speculative streaming: an INT2 draft quantized from the same
    //     checkpoint proposes tokens and the INT4 target verifies them
    //     in one batched pass per round. Greedy verification keeps the
    //     output bit-identical to plain decoding, so the draft buys
    //     speed only. Each speculative session rents a second K/V state
    //     from the same arena; the default auto-sized arena
    //     (max_sessions = 64 full-context states) covers the doubled
    //     reservation for these 32 streams.
    let draft_qm = quantize_model(&ck, Bits::Int2, &Method::SplitQuant(SplitConfig::default()))?;
    let draft = Arc::new(PackedModel::from_qmodel(&draft_qm)?);
    let spec_server = Server::start(
        Backend::Packed(Box::new(PackedModel::from_qmodel(&device_qm)?)),
        ServerConfig {
            draft: Some(draft),
            draft_k: 4,
            ..Default::default()
        },
    )?;
    let t_spec = Instant::now();
    let spec_streams: Vec<_> = problems[..n_gen]
        .iter()
        .map(|p| {
            spec_server.submit_generate(GenerateRequest {
                prompt: p.prompt.clone(),
                max_tokens: 8,
                deadline: None,
            })
        })
        .collect::<Result<_>>()?;
    let mut spec_tokens = 0usize;
    for s in spec_streams {
        spec_tokens += s.wait()?.tokens.len();
    }
    let spec_wall = t_spec.elapsed();
    println!("\n-- speculative generation ({n_gen} streams, INT2 draft -> INT4 target) --");
    println!(
        "decoded {spec_tokens} tokens in {} ({:.0} tok/s); kv blocks in use after drain: {}",
        format_duration(spec_wall),
        spec_tokens as f64 / spec_wall.as_secs_f64().max(1e-9),
        spec_server.kv_blocks_in_use()
    );

    // 7. The deployment's own telemetry, folded from everything above.
    let snap = obs::snapshot();
    let ms = |ns: f64| ns / 1e6;
    println!("\n-- final metrics snapshot --");
    if let Some(h) = snap.hist(obs::names::SERVE_TTFT_NS) {
        println!(
            "ttft p50 {:.2}ms  p99 {:.2}ms  ({} requests)",
            ms(h.percentile(50.0)),
            ms(h.percentile(99.0)),
            h.count
        );
    }
    if let Some(h) = snap.hist(obs::names::SERVE_LATENCY_NS) {
        println!(
            "latency p50 {:.2}ms  p99 {:.2}ms",
            ms(h.percentile(50.0)),
            ms(h.percentile(99.0))
        );
    }
    let tokens = snap.counter(obs::names::SERVE_TOKENS_TOTAL).unwrap_or(0);
    let uptime = snap.uptime.as_secs_f64();
    println!(
        "generated tokens: {tokens} ({:.0} tok/s over {uptime:.1}s uptime)",
        tokens as f64 / uptime.max(1e-9)
    );
    let peak = snap.gauge_peak(obs::names::KV_BLOCKS_IN_USE).unwrap_or(0);
    println!("kv arena occupancy high-water mark: {peak} blocks");
    let drafted = snap.counter(obs::names::SPECDEC_DRAFT_TOKENS).unwrap_or(0);
    let accepted = snap.counter(obs::names::SPECDEC_ACCEPTED_TOKENS).unwrap_or(0);
    if drafted > 0 {
        println!(
            "speculative acceptance: {:.1}% ({accepted}/{drafted} draft tokens accepted)",
            100.0 * accepted as f64 / drafted as f64
        );
    }

    std::fs::remove_file(&packed_path).ok();
    Ok(())
}
