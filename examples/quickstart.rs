//! Quickstart: SplitQuantV2 on a single weight matrix, end to end.
//!
//! Shows the paper's core mechanism in ~60 lines of user code:
//!  1. a weight matrix with outliers (the LLM regime),
//!  2. plain INT4 linear quantization → poor resolution,
//!  3. k-means split into lower/middle/upper planes → each plane gets
//!     its own (much larger) scaling factor → error collapses,
//!  4. functional equivalence of the FP split.
//!
//! Run: cargo run --release --example quickstart

use splitquant::quant::{self, Bits, QuantParams};
use splitquant::split::{self, SplitConfig};
use splitquant::tensor::Tensor;
use splitquant::util::rng::Rng;
use splitquant::util::stats::mse;

fn main() {
    // 1. An LLM-like weight matrix: dense small values + a few outliers.
    let mut rng = Rng::new(42);
    let (out_d, in_d) = (256, 256);
    let mut data: Vec<f32> = (0..out_d * in_d).map(|_| rng.normal_f32(0.0, 0.04)).collect();
    for _ in 0..60 {
        let i = rng.below(data.len());
        data[i] = rng.uniform_in(1.0, 2.5) * if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
    }
    let w = Tensor::new(&[out_d, in_d], data);
    println!("weight matrix {}x{}  range [{:.3}, {:.3}]", out_d, in_d, w.min(), w.max());

    // 2. Baseline INT4 linear quantization (paper Eq. 1-3).
    let baseline = QuantParams::of_tensor(Bits::Int4, &w);
    let base_q = quant::fake_quantize(&w, Bits::Int4);
    println!("\n-- baseline INT4 --");
    println!("scaling factor S = {:.2}  (step {:.4})", baseline.scale, baseline.step());
    println!("weight MSE       = {:.3e}", mse(w.data(), base_q.data()));

    // 3. SplitQuantV2: k-means(k=3) split, then quantize each plane.
    let cfg = SplitConfig::default();
    let qsl = split::split_quantize(&w, &cfg, Bits::Int4);
    println!("\n-- SplitQuantV2 INT4 (k={}) --", qsl.k());
    for (i, plane) in qsl.planes.iter().enumerate() {
        let p = plane.params[0];
        println!(
            "plane {i}: {:>9.1} weights  S = {:>8.2}  ({}x the baseline resolution)",
            qsl.clustering.sizes[i],
            p.scale,
            (p.scale / baseline.scale) as i64
        );
    }
    let eff = qsl.effective_weight();
    println!("weight MSE       = {:.3e}", mse(w.data(), eff.data()));
    let rep = split::resolution_report(&w, &cfg, Bits::Int4);
    println!("MSE improvement  = {:.0}x", rep.mse_gain);

    // 4. Functional preservation (§4.1): the FP split planes sum back to
    //    the original weights bit-exactly.
    let fp_split = split::split_tensor(&w, &cfg);
    let reconstructed = fp_split.reconstruct();
    assert_eq!(reconstructed.data(), w.data());
    println!("\nFP split reconstruction: bit-exact ✓");

    // 5. Size cost (§5): k dense INT4 planes = 3/8 of FP32, vs 1/8 plain.
    let fp_bytes = (w.len() * 4) as f64;
    println!(
        "sizes: FP32 {:.0} KiB | INT4 {:.0} KiB (1/8) | INT4+SQv2 {:.0} KiB (3/8)",
        fp_bytes / 1024.0,
        quant::quantize_per_tensor(&w, Bits::Int4).packed_len() as f64 / 1024.0,
        qsl.packed_len() as f64 / 1024.0
    );
}
