//! Property-based invariant sweeps (hand-rolled generators over the
//! crate's deterministic PRNG — proptest is unavailable offline).
//!
//! Every property runs across many randomized trials with distinct
//! seeds; failures print the seed so the case can be replayed.

use splitquant::kmeans;
use splitquant::quant::{self, Bits, QuantParams};
use splitquant::split::{self, SplitConfig};
use splitquant::tensor::Tensor;
use splitquant::util::rng::Rng;
use splitquant::util::stats::mse;

const TRIALS: u64 = 40;

/// Random tensor whose distribution varies by trial: gaussian, heavy
/// tailed, bimodal, constant-ish, tiny-range.
fn random_tensor(seed: u64) -> Tensor {
    let mut r = Rng::new(seed);
    let rows = 4 + r.below(24);
    let cols = 4 + r.below(24);
    let kind = r.below(5);
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| match kind {
            0 => r.normal_f32(0.0, 1.0),
            1 => (r.heavy_tailed(3.0) * 0.1) as f32,
            2 => {
                if r.uniform() < 0.5 {
                    r.normal_f32(-2.0, 0.1)
                } else {
                    r.normal_f32(2.0, 0.1)
                }
            }
            3 => 0.7 + r.normal_f32(0.0, 1e-4),
            _ => r.normal_f32(0.0, 1e-3),
        })
        .collect();
    Tensor::new(&[rows, cols], data)
}

#[test]
fn prop_quant_error_bounded_by_half_step() {
    for seed in 0..TRIALS {
        let t = random_tensor(seed);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let p = QuantParams::of_tensor(bits, &t);
            let q = quant::quantize_per_tensor(&t, bits);
            let dq = q.dequantize();
            let bound = 0.5 * p.step() + 1e-5;
            for (a, b) in t.data().iter().zip(dq.data()) {
                assert!(
                    ((a - b) as f64).abs() <= bound,
                    "seed {seed} {bits:?}: |{a}-{b}| > {bound}"
                );
            }
        }
    }
}

#[test]
fn prop_zero_always_exact() {
    for seed in 0..TRIALS {
        let mut r = Rng::new(seed + 1000);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let lo = r.uniform_in(-10.0, 10.0);
            let hi = lo + r.uniform_in(0.0, 10.0);
            let p = QuantParams::from_range(bits, lo.min(hi), hi.max(lo));
            assert_eq!(
                p.dequantize(p.quantize(0.0)),
                0.0,
                "seed {seed} {bits:?} [{lo},{hi}]"
            );
        }
    }
}

#[test]
fn prop_split_reconstruction_bit_exact() {
    for seed in 0..TRIALS {
        let t = random_tensor(seed + 2000);
        for k in [2usize, 3, 4] {
            let sl = split::split_tensor(&t, &SplitConfig::with_k(k));
            assert_eq!(
                sl.reconstruct().data(),
                t.data(),
                "seed {seed} k={k}: ΣWⱼ ≠ W"
            );
        }
    }
}

#[test]
fn prop_split_never_increases_quant_mse() {
    for seed in 0..TRIALS {
        let t = random_tensor(seed + 3000);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let base = quant::quant_mse(&t, bits);
            let eff = split::split_fake_quantize(&t, &SplitConfig::default(), bits);
            let split_mse = mse(t.data(), eff.data());
            assert!(
                split_mse <= base * 1.000001 + 1e-12,
                "seed {seed} {bits:?}: split {split_mse} > baseline {base}"
            );
        }
    }
}

#[test]
fn prop_fused_split_equals_staged() {
    for seed in 0..TRIALS {
        let t = random_tensor(seed + 4000);
        let cfg = SplitConfig::default();
        let fused = split::split_quantize(&t, &cfg, Bits::Int4);
        let staged = split::quantize_split(&split::split_tensor(&t, &cfg), Bits::Int4);
        assert_eq!(fused.k(), staged.k(), "seed {seed}");
        for (a, b) in fused.planes.iter().zip(&staged.planes) {
            assert_eq!(a.plane.data(), b.plane.data(), "seed {seed}");
            assert_eq!(a.params[0], b.params[0], "seed {seed}");
        }
    }
}

#[test]
fn prop_kmeans_inertia_monotone_in_k() {
    for seed in 0..TRIALS {
        let t = random_tensor(seed + 5000);
        let mut last = f64::INFINITY;
        for k in 1..=4 {
            let c = kmeans::kmeans_auto(t.data(), k);
            assert!(
                c.inertia <= last + 1e-9,
                "seed {seed} k={k}: {} > {last}",
                c.inertia
            );
            last = c.inertia;
        }
    }
}

#[test]
fn prop_kmeans_assignment_is_nearest_centroid() {
    for seed in 0..TRIALS {
        let t = random_tensor(seed + 6000);
        let c = kmeans::kmeans_auto(t.data(), 3);
        for &v in t.data().iter().take(200) {
            let assigned = c.assign(v);
            let d_assigned = (v as f64 - c.centroids[assigned]).abs();
            for (j, &cj) in c.centroids.iter().enumerate() {
                assert!(
                    d_assigned <= (v as f64 - cj).abs() + 1e-9,
                    "seed {seed}: {v} assigned {assigned} but {j} closer"
                );
            }
        }
    }
}

#[test]
fn prop_pack_roundtrip_arbitrary_lengths() {
    for seed in 0..TRIALS {
        let mut r = Rng::new(seed + 7000);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let n = r.below(300);
            let vals: Vec<i8> = (0..n)
                .map(|_| {
                    (bits.qmin() + r.below((bits.qmax() - bits.qmin() + 1) as usize) as i32)
                        as i8
                })
                .collect();
            let packed = quant::pack::pack(&vals, bits);
            let back = quant::pack::unpack(&packed, n, bits).unwrap();
            assert_eq!(back, vals, "seed {seed} {bits:?} n={n}");
        }
    }
}

#[test]
fn prop_per_channel_step_never_wider_than_per_tensor() {
    // The true invariant: every row's quantization *step* (1/S) is at
    // most the whole-tensor step, because row ranges ⊆ tensor range.
    // (Realized MSE can occasionally favor per-tensor on near-constant
    // tensors through grid-alignment luck, so we assert on resolution,
    // plus a loose 2x factor on MSE.)
    for seed in 0..TRIALS {
        let t = random_tensor(seed + 8000);
        for bits in [Bits::Int4, Bits::Int8] {
            let pt_step = QuantParams::of_tensor(bits, &t).step();
            let pc = quant::quantize_per_channel(&t, bits);
            for (r, p) in pc.params.iter().enumerate() {
                assert!(
                    p.step() <= pt_step * 1.000001,
                    "seed {seed} {bits:?} row {r}: step {} > tensor step {pt_step}",
                    p.step()
                );
            }
            let m_pt = mse(t.data(), quant::quantize_per_tensor(&t, bits).dequantize().data());
            let m_pc = mse(t.data(), pc.dequantize().data());
            assert!(
                m_pc <= m_pt * 2.0 + 1e-12,
                "seed {seed} {bits:?}: pc {m_pc} ≫ pt {m_pt}"
            );
        }
    }
}

#[test]
fn prop_ocs_expansion_preserves_function() {
    for seed in 0..TRIALS {
        let t = random_tensor(seed + 9000);
        let mut r = Rng::new(seed);
        let ratio = r.uniform_in(0.0, 0.2) as f64;
        let exp = split::ocs::ocs_expand(&t, ratio);
        assert!(
            exp.reconstruct().allclose(&t, 1e-5),
            "seed {seed} ratio {ratio}"
        );
    }
}

#[test]
fn prop_sqtz_roundtrip_random_models() {
    use splitquant::io::{from_bytes, to_bytes, Entry};
    for seed in 0..20 {
        let t = random_tensor(seed + 10_000);
        let entries = vec![Entry::f32("w", &t)];
        let bytes = to_bytes(&entries, &Default::default(), None);
        let c = from_bytes(&bytes).unwrap();
        assert_eq!(c.f32("w").unwrap(), t, "seed {seed}");
    }
}

#[test]
fn prop_quantized_model_effective_close_at_int8() {
    use splitquant::model::{Checkpoint, PicoLlamaConfig};
    for seed in 0..8 {
        let ck = Checkpoint::random_init(&PicoLlamaConfig::test(), seed + 11_000);
        for method in [
            splitquant::model::quantized::Method::Baseline,
            splitquant::model::quantized::Method::SplitQuant(SplitConfig::default()),
        ] {
            let qm =
                splitquant::model::quantized::quantize_model(&ck, Bits::Int8, &method).unwrap();
            let eff = qm.effective_checkpoint();
            for (name, t) in &ck.tensors {
                let e = eff.tensors.get(name).unwrap();
                let m = mse(t.data(), e.data());
                assert!(m < 1e-4, "seed {seed} {name}: INT8 mse {m}");
            }
        }
    }
}
