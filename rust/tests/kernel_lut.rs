//! Property tests for the blocked kernel engine (DESIGN.md §7, §9):
//! the LUT, SIMD, blocked and row-parallel paths are pinned against
//! the scalar unpack-whole-row oracle (`KernelImpl::Scalar`) across
//! every bit width, odd column counts (tail lanes), per-row
//! parameters, empty-cluster split planes and seq ∈ {1, 2, 7} — ≤1e-5
//! relative tolerance for the f32 paths, *exact* integer equality for
//! the unpacked levels and the INT8-activation path. Plus the
//! accumulate contract (no double-accumulate across plane kinds), the
//! chunked ≡ full decode property on every kernel implementation, and
//! the runtime-dispatch contract (`Auto` resolution and the
//! `SPLITQUANT_NO_SIMD` fallback — the env round-trip lives here, in
//! the integration binary, because a test binary owns its process env;
//! CI runs this suite once with the veto set and once without).

use std::sync::Arc;

use splitquant::kernels::{self, KernelImpl, KernelScratch, PackedLinear, PackedMatrix};
use splitquant::kmeans::Clustering1D;
use splitquant::model::decode::DecodeState;
use splitquant::model::packed::{pack_linear, PackedModel};
use splitquant::model::quantized::{quantize_model, Method, QuantParam};
use splitquant::model::{forward::Workspace, Checkpoint, PicoLlamaConfig};
use splitquant::quant::{self, pack, Bits, QuantParams};
use splitquant::split::{split_quantize, QuantizedSplitLayer, SplitConfig, Strategy};
use splitquant::tensor::{Tensor, TensorI8};
use splitquant::util::pool::Pool;
use splitquant::util::rng::Rng;
use splitquant::util::stats::max_abs_diff;

/// LLM-like weights: mostly small values, a few large outliers.
fn heavy_tensor(seed: u64, rows: usize, cols: usize) -> Tensor {
    let mut r = Rng::new(seed);
    let mut data: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 0.05)).collect();
    let n_out = (data.len() / 40).max(1);
    for _ in 0..n_out {
        let i = r.below(data.len());
        data[i] = r.uniform_in(1.0, 2.5) * if r.uniform() < 0.5 { -1.0 } else { 1.0 };
    }
    Tensor::new(&[rows, cols], data)
}

fn random_x(seed: u64, seq: usize, cols: usize) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut data = vec![0.0f32; seq * cols];
    r.fill_normal(&mut data, 0.0, 1.0);
    data
}

fn scratch_with(imp: KernelImpl) -> KernelScratch {
    let mut s = KernelScratch::new();
    s.set_kernel_impl(imp);
    s
}

fn parallel_scratch_with(imp: KernelImpl, workers: usize) -> KernelScratch {
    let mut s = KernelScratch::new();
    s.set_kernel_impl(imp);
    s.set_row_pool(Some(Arc::new(Pool::new(workers))));
    s.set_min_par_work(0); // force sharding even on tiny test shapes
    s
}

fn parallel_scratch(workers: usize) -> KernelScratch {
    // Explicitly LUT: the bit-exact sharded ≡ serial assertions below
    // compare against the serial LUT result, so the sharded scratch
    // must not let Auto resolve to SIMD on capable hosts.
    parallel_scratch_with(KernelImpl::Lut, workers)
}

/// A degenerate split layer whose second plane is an empty cluster:
/// every level 0, scale 1, zero-point 0 — it must contribute exactly 0.
fn with_empty_cluster(w: &Tensor, bits: Bits) -> QuantParam {
    let qa = quant::quantize_per_tensor(w, bits);
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let zero_plane = quant::QuantizedTensor {
        plane: TensorI8::zeros(&[rows, cols]),
        granularity: quant::Granularity::PerTensor,
        params: vec![QuantParams::from_range(bits, 0.0, 0.0)],
    };
    let clustering = Clustering1D {
        centroids: vec![0.0, 0.0],
        boundaries: vec![f64::INFINITY],
        inertia: 0.0,
        sizes: vec![w.len() as f64, 0.0],
        member_ranges: None,
    };
    QuantParam::Split(QuantizedSplitLayer {
        planes: vec![qa, zero_plane],
        clustering,
        strategy: Strategy::MaskedSum,
    })
}

/// Every (bits × shape × param-kind × seq) cell: the LUT and SIMD
/// paths and their row-parallel variants must stay within 1e-5
/// relative of the scalar oracle, and each impl's sharded run must
/// agree with its own serial run bit-for-bit at seq==1. On hosts
/// without the CPU features the SIMD arm resolves to LUT and the
/// assertions still hold (they become LUT-vs-LUT).
#[test]
fn lut_blocked_and_row_parallel_match_scalar_oracle() {
    let mut seed = 500;
    for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
        // Odd cols exercise the tail lanes of every byte width; 513/515
        // straddle one LUT_BLOCK boundary; 37 rows exercises the 4-row
        // tile's 1-row tail; 130 rows splits into many row-parallel
        // shards (out_dim must clear the 32-row sharding floor — the
        // smaller shapes run the parallel arm serially by design).
        for (rows, cols) in [(5usize, 7usize), (37, 33), (130, 129), (8, 513), (4, 515)] {
            seed += 1;
            let w = heavy_tensor(seed, rows, cols);
            let params: Vec<(&str, QuantParam)> = vec![
                ("plain", QuantParam::Plain(quant::quantize_per_tensor(&w, bits))),
                (
                    "per-channel",
                    QuantParam::Plain(quant::quantize_per_channel(&w, bits)),
                ),
                (
                    "split",
                    QuantParam::Split(split_quantize(&w, &SplitConfig::default(), bits)),
                ),
                ("empty-cluster", with_empty_cluster(&w, bits)),
            ];
            for (kind, qp) in &params {
                let lin = pack_linear(qp).unwrap();
                for seq in [1usize, 2, 7] {
                    let label = format!("{bits:?} {rows}x{cols} {kind} seq={seq}");
                    let x = random_x(seed * 13 + seq as u64, seq, cols);
                    let mut y_scalar = vec![0.0f32; seq * rows];
                    let mut y_lut = vec![0.0f32; seq * rows];
                    kernels::gemm(
                        &mut y_scalar,
                        &x,
                        seq,
                        &lin,
                        &mut scratch_with(KernelImpl::Scalar),
                    );
                    kernels::gemm(&mut y_lut, &x, seq, &lin, &mut scratch_with(KernelImpl::Lut));
                    let mut y_simd = vec![0.0f32; seq * rows];
                    kernels::gemm(&mut y_simd, &x, seq, &lin, &mut scratch_with(KernelImpl::Simd));
                    let scale =
                        y_scalar.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1.0) as f64;
                    assert!(
                        max_abs_diff(&y_lut, &y_scalar) < 1e-5 * scale,
                        "{label}: lut drifted {} (magnitude {scale})",
                        max_abs_diff(&y_lut, &y_scalar)
                    );
                    assert!(
                        max_abs_diff(&y_simd, &y_scalar) < 1e-5 * scale,
                        "{label}: simd drifted {} (magnitude {scale})",
                        max_abs_diff(&y_simd, &y_scalar)
                    );
                    if seq == 1 {
                        let mut y_par = vec![0.0f32; rows];
                        kernels::gemm(&mut y_par, &x, 1, &lin, &mut parallel_scratch(4));
                        assert_eq!(y_par, y_lut, "{label}: row sharding changed results");
                        // Pin the serial reference to the sharded
                        // scratch's *resolved* impl (never Auto), so the
                        // comparison stays bit-exact even if the env-veto
                        // test flips `Auto` resolution concurrently.
                        let mut spar = parallel_scratch_with(KernelImpl::Simd, 4);
                        let mut sserial = scratch_with(spar.effective_impl());
                        let mut y_sref = vec![0.0f32; rows];
                        kernels::gemm(&mut y_sref, &x, 1, &lin, &mut sserial);
                        let mut y_spar = vec![0.0f32; rows];
                        kernels::gemm(&mut y_spar, &x, 1, &lin, &mut spar);
                        assert_eq!(y_spar, y_sref, "{label}: simd sharding changed results");
                    }
                }
            }
        }
    }
}

/// The INT8-activation path is exact integer arithmetic after the
/// activation quantization, so its LUT-blocked variant must be
/// bit-identical to the scalar oracle — across split planes too.
#[test]
fn int8_lut_path_is_bit_identical_to_scalar_across_planes() {
    for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
        let w = heavy_tensor(900 + bits.width() as u64, 9, 521);
        for qp in [
            QuantParam::Plain(quant::quantize_per_channel(&w, bits)),
            QuantParam::Split(split_quantize(&w, &SplitConfig::default(), bits)),
        ] {
            let lin = pack_linear(&qp).unwrap();
            for seq in [1usize, 2, 7] {
                let x = random_x(7 + seq as u64, seq, 521);
                let mut a = vec![0.0f32; seq * 9];
                let mut b = vec![0.0f32; seq * 9];
                let mut c = vec![0.0f32; seq * 9];
                kernels::gemm_int8(&mut a, &x, seq, &lin, &mut scratch_with(KernelImpl::Lut));
                kernels::gemm_int8(&mut b, &x, seq, &lin, &mut scratch_with(KernelImpl::Scalar));
                kernels::gemm_int8(&mut c, &x, seq, &lin, &mut scratch_with(KernelImpl::Simd));
                assert_eq!(a, b, "{bits:?} seq={seq}: integer paths diverged");
                assert_eq!(c, b, "{bits:?} seq={seq}: simd integer path diverged");
            }
        }
    }
}

/// The byte tables hold the *exact* zero-adjusted integer levels: every
/// lane of every byte equals the packed accessor's `q − z`, in both the
/// f32 and i32 flavors.
#[test]
fn lut_tables_pin_exact_integer_levels() {
    for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
        let lanes = pack::lanes_per_byte(bits);
        for z in bits.qmin()..=bits.qmax() {
            let f = kernels::lut_table_f32(bits, z);
            let i = kernels::lut_table_i32(bits, z);
            assert_eq!(f.len(), 256 * lanes, "{bits:?}");
            for byte in 0..=255u8 {
                for lane in 0..lanes {
                    let level = pack::get_packed(&[byte], lane, bits) as i32 - z;
                    assert_eq!(i[byte as usize * lanes + lane], level, "{bits:?} z={z} {byte}");
                    assert_eq!(
                        f[byte as usize * lanes + lane],
                        level as f32,
                        "{bits:?} z={z} byte={byte} lane={lane}"
                    );
                }
            }
        }
    }
}

/// One-hot activations read single weights through the full public
/// kernel: the output must equal `(q − z) / S` computed from the scalar
/// accessor *exactly*, on both implementations — the end-to-end form of
/// the exact-level guarantee.
#[test]
fn one_hot_gemv_reads_exact_levels_on_both_impls() {
    for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
        let w = heavy_tensor(40 + bits.width() as u64, 6, 21);
        let q = quant::quantize_per_channel(&w, bits);
        let m = PackedMatrix::from_quantized(&q).unwrap();
        let lin = PackedLinear::from_planes(vec![m.clone()]).unwrap();
        for c in [0usize, 1, 19, 20] {
            let mut x = vec![0.0f32; 21];
            x[c] = 1.0;
            for imp in [KernelImpl::Lut, KernelImpl::Scalar, KernelImpl::Simd] {
                let mut y = vec![0.0f32; 6];
                kernels::gemv(&mut y, &x, &lin, &mut scratch_with(imp));
                for (o, &got) in y.iter().enumerate() {
                    let p = m.param_of_row(o);
                    let level = m.get(o, c) as i32 - p.zero_point;
                    let want = (level as f64 / p.scale) as f32;
                    assert_eq!(got, want, "{bits:?} {imp:?} ({o},{c})");
                }
            }
        }
    }
}

/// The accumulate contract: entry points overwrite, helpers `+=`.
/// Running the same gemm twice into the same (dirty) output must give
/// the same answer for every linear form — a double-accumulate anywhere
/// (plain plane, k split planes, dense fallback) fails this.
#[test]
fn no_double_accumulate_across_plain_split_and_dense() {
    let w = heavy_tensor(60, 11, 29);
    let forms: Vec<(&str, PackedLinear)> = vec![
        (
            "plain",
            pack_linear(&QuantParam::Plain(quant::quantize_per_tensor(&w, Bits::Int4))).unwrap(),
        ),
        (
            "split",
            pack_linear(&QuantParam::Split(split_quantize(
                &w,
                &SplitConfig::default(),
                Bits::Int4,
            )))
            .unwrap(),
        ),
        (
            "dense",
            pack_linear(&QuantParam::OcsEffective {
                effective: w.clone(),
                packed_len: 0,
            })
            .unwrap(),
        ),
    ];
    let x = random_x(61, 2, 29);
    for imp in [KernelImpl::Lut, KernelImpl::Scalar, KernelImpl::Simd] {
        let mut scratch = scratch_with(imp);
        for (kind, lin) in &forms {
            let mut first = vec![0.0f32; 2 * 11];
            kernels::gemm(&mut first, &x, 2, lin, &mut scratch);
            // Re-run into the dirty buffer: entry points must zero-fill.
            let mut second = first.clone();
            kernels::gemm(&mut second, &x, 2, lin, &mut scratch);
            assert_eq!(first, second, "{imp:?} {kind}: gemm accumulated into dirty output");

            let mut int_first = vec![0.0f32; 2 * 11];
            kernels::gemm_int8(&mut int_first, &x, 2, lin, &mut scratch);
            let mut int_second = int_first.clone();
            kernels::gemm_int8(&mut int_second, &x, 2, lin, &mut scratch);
            assert_eq!(int_first, int_second, "{imp:?} {kind}: gemm_int8 double-accumulated");
        }
    }
    // gemm_matrix (the tied-LM-head path) honors the same contract.
    let q = quant::quantize_per_channel(&w, Bits::Int8);
    let m = PackedMatrix::from_quantized(&q).unwrap();
    let mut scratch = KernelScratch::new();
    let mut first = vec![0.0f32; 2 * 11];
    kernels::gemm_matrix(&mut first, &x, 2, &m, &mut scratch);
    let mut second = first.clone();
    kernels::gemm_matrix(&mut second, &x, 2, &m, &mut scratch);
    assert_eq!(first, second, "gemm_matrix double-accumulated");
}

/// Row-parallel sharding is deterministic: repeated runs and different
/// worker counts all equal the serial LUT result bit-for-bit (the
/// plane-outer/row-inner order is preserved inside every shard).
#[test]
fn row_parallel_is_deterministic_across_worker_counts() {
    let w = heavy_tensor(70, 67, 130);
    let qp = QuantParam::Split(split_quantize(&w, &SplitConfig::default(), Bits::Int4));
    let lin = pack_linear(&qp).unwrap();
    let x = random_x(71, 1, 130);
    let mut serial = vec![0.0f32; 67];
    kernels::gemv(&mut serial, &x, &lin, &mut scratch_with(KernelImpl::Lut));
    for workers in [2usize, 3, 8] {
        let mut scratch = parallel_scratch(workers);
        for run in 0..3 {
            let mut y = vec![0.0f32; 67];
            kernels::gemv(&mut y, &x, &lin, &mut scratch);
            assert_eq!(y, serial, "workers={workers} run={run}");
        }
    }
}

fn test_checkpoint() -> Checkpoint {
    let mut ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 91);
    ck.amplify_outliers(0.002, 10.0, 4);
    ck
}

/// The decode-state acceptance property on the packed engine, per
/// kernel implementation: chunked extension through a DecodeState is
/// bit-identical to the whole-sequence forward (each blocked path's
/// per-(row, block) order is seq-independent by construction), and
/// every implementation's logits stay within FP tolerance of the
/// scalar oracle's.
#[test]
fn packed_chunked_extend_equals_full_forward_on_both_impls() {
    let ck = test_checkpoint();
    let toks = [1usize, 6, 11, 3, 2, 9, 4, 7];
    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let mut ws = Workspace::new(&ck.config, 16);
    let mut full_logits = Vec::new();
    for imp in [KernelImpl::Lut, KernelImpl::Simd, KernelImpl::Scalar] {
        let mut scratch = pm.prewarmed_scratch();
        scratch.set_kernel_impl(imp);
        let full = pm.forward_with(&toks, &mut ws, &mut scratch).unwrap();
        for split in [1usize, 3, 7] {
            let mut state = DecodeState::new(&ck.config);
            let head = pm
                .forward_extend(&toks[..split], 0, &mut ws, &mut scratch, &mut state)
                .unwrap();
            let tail = pm
                .forward_extend(&toks[split..], split, &mut ws, &mut scratch, &mut state)
                .unwrap();
            for t in 0..split {
                assert_eq!(head.row(t), full.row(t), "{imp:?} split={split} head row {t}");
            }
            for t in split..toks.len() {
                assert_eq!(
                    tail.row(t - split),
                    full.row(t),
                    "{imp:?} split={split} tail row {t}"
                );
            }
        }
        full_logits.push(full);
    }
    // The scalar oracle ran last; pin every blocked impl against it.
    let oracle = &full_logits[2];
    let scale = oracle.data().iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1.0) as f64;
    for (name, logits) in [("lut", &full_logits[0]), ("simd", &full_logits[1])] {
        let diff = max_abs_diff(logits.data(), oracle.data());
        assert!(diff < 1e-4 * scale, "{name} drifted {diff} from scalar (magnitude {scale})");
    }
}

/// Row-parallel scoring through the full packed model matches the
/// serial engine exactly (the eval/serving thread-budget path).
#[test]
fn packed_forward_with_row_pool_matches_serial() {
    let ck = test_checkpoint();
    let qm = quantize_model(&ck, Bits::Int8, &Method::Baseline).unwrap();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let toks = [2usize, 5, 1, 8];
    let mut ws = Workspace::new(&ck.config, 16);
    let mut serial = pm.prewarmed_scratch();
    let mut par = pm.prewarmed_scratch();
    par.set_row_pool(Some(Arc::new(Pool::new(4))));
    par.set_min_par_work(0);
    let mut sa = DecodeState::new(&ck.config);
    let mut sb = DecodeState::new(&ck.config);
    for (i, &t) in toks.iter().enumerate() {
        let a = pm.forward_extend(&[t], i, &mut ws, &mut serial, &mut sa).unwrap();
        let b = pm.forward_extend(&[t], i, &mut ws, &mut par, &mut sb).unwrap();
        assert_eq!(a, b, "token {i}: row-parallel decode diverged");
    }
}

/// The runtime-dispatch contract end to end: `Auto` resolves against
/// the host, and setting `SPLITQUANT_NO_SIMD` makes both `Auto` and
/// `Simd` requests fall back to the LUT impl — with correct numerics
/// under the fallback. This is the one test that mutates the process
/// environment; it lives in this integration binary (not the lib unit
/// tests) so it cannot race concurrently-running unit tests that
/// consult `simd_available()`, and it restores the prior value so the
/// suite behaves identically whether CI exported the veto or not.
#[test]
fn auto_dispatch_resolves_and_env_override_falls_back_to_lut() {
    let prior = std::env::var_os(kernels::NO_SIMD_ENV);
    if prior.is_none() {
        // Unvetoed: Auto must resolve to SIMD exactly when the host
        // has the features.
        let s = KernelScratch::new();
        assert_eq!(s.kernel_impl(), KernelImpl::Auto);
        let want = if kernels::simd_available() { KernelImpl::Simd } else { KernelImpl::Lut };
        assert_eq!(s.effective_impl(), want, "Auto must track the host");
    }

    std::env::set_var(kernels::NO_SIMD_ENV, "1");
    assert!(!kernels::simd_available(), "the env veto must disable SIMD dispatch");
    let vetoed = KernelScratch::new();
    assert_eq!(vetoed.effective_impl(), KernelImpl::Lut, "vetoed Auto must resolve to Lut");
    let mut forced = KernelScratch::new();
    forced.set_kernel_impl(KernelImpl::Simd);
    assert_eq!(forced.kernel_impl(), KernelImpl::Simd, "the request is preserved");
    assert_eq!(forced.effective_impl(), KernelImpl::Lut, "vetoed Simd must fall back to Lut");

    // The fallback is not just a label: numerics under the veto match
    // the scalar oracle.
    let w = heavy_tensor(81, 9, 37);
    let qp = QuantParam::Plain(quant::quantize_per_channel(&w, Bits::Int4));
    let lin = pack_linear(&qp).unwrap();
    let x = random_x(82, 1, 37);
    let mut y_fallback = vec![0.0f32; 9];
    kernels::gemv(&mut y_fallback, &x, &lin, &mut forced);
    let mut y_scalar = vec![0.0f32; 9];
    kernels::gemv(&mut y_scalar, &x, &lin, &mut scratch_with(KernelImpl::Scalar));
    let scale = y_scalar.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1.0) as f64;
    assert!(
        max_abs_diff(&y_fallback, &y_scalar) < 1e-5 * scale,
        "vetoed-fallback gemv drifted from the scalar oracle"
    );

    match prior {
        Some(v) => std::env::set_var(kernels::NO_SIMD_ENV, v),
        None => std::env::remove_var(kernels::NO_SIMD_ENV),
    }
}
