//! Integration suite for the telemetry subsystem (DESIGN.md §10):
//!
//! * **Histogram fidelity** — log-bucketed percentiles must track a
//!   sorted-vector oracle within the documented ≤25% relative bucket
//!   width.
//! * **Shard-merge exactness** — concurrent recorders across threads
//!   must fold to exact counts and sums (each event lands in exactly
//!   one shard; the merge loses nothing).
//! * **Exposition** — label values render escaped per the Prometheus
//!   text format, and histogram families emit cumulative buckets.
//! * **Serving integration** — the `reason`-labeled shed counters must
//!   sum to exactly the typed [`ServeError`]s clients observe, and
//!   continuous-batched generation must stay bit-identical to the
//!   sequential greedy oracle with recording enabled.
//!
//! Every test here switches recording *on* and never off (the flag is
//! process-global; tests in this binary run concurrently), and asserts
//! on deltas or uniquely named series.

use splitquant::coordinator::server::{Backend, GenerateRequest, ServeError, Server, ServerConfig};
use splitquant::data::{generate_problems, FactWorld, McqProblem};
use splitquant::model::decode::DecodeState;
use splitquant::model::forward::Workspace;
use splitquant::model::packed::PackedModel;
use splitquant::model::quantized::{quantize_model, Method, QuantizedModel};
use splitquant::model::{Checkpoint, PicoLlamaConfig};
use splitquant::obs;
use splitquant::quant::Bits;
use splitquant::split::SplitConfig;
use splitquant::util::stats::percentile_sorted;

fn setup() -> (QuantizedModel, Vec<McqProblem>) {
    let world = FactWorld::generate(16, 4, 8, 1);
    let mut cfg = PicoLlamaConfig::test();
    cfg.vocab = world.vocab_size();
    let ck = Checkpoint::random_init(&cfg, 7);
    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
    let problems = generate_problems(&world, 12, 5);
    (qm, problems)
}

/// Sequential greedy oracle on the packed engine (owned, contiguous
/// decode state — the pre-serving code path).
fn packed_oracle(pm: &PackedModel, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let mut ws = Workspace::new(&pm.config, pm.config.max_seq);
    let mut scratch = pm.prewarmed_scratch();
    let mut state = DecodeState::new(&pm.config);
    pm.generate_greedy(prompt, n_new, &mut ws, &mut scratch, &mut state)
        .unwrap()
}

fn shed_count(snap: &obs::MetricsSnapshot, reason: &str) -> u64 {
    let series = obs::series(obs::names::SERVE_SHED_TOTAL, &[("reason", reason)]);
    snap.counter(&series).unwrap_or(0)
}

fn counter_of(snap: &obs::MetricsSnapshot, name: &str) -> u64 {
    snap.counter(name).unwrap_or(0)
}

#[test]
fn histogram_percentiles_track_sorted_oracle() {
    obs::set_enabled(true);
    let h = obs::histogram("obs_itest_percentile_ns");
    // Deterministic LCG spread across ~18 octaves, well past the exact
    // 0..=3 range, so every observation exercises log bucketing.
    let mut x = 0x9e3779b97f4a7c15u64;
    let mut values = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let v = (x >> 33) % 1_000_000 + 4;
        h.record(v);
        values.push(v as f64);
    }
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let data = h.merged();
    assert_eq!(data.count, 10_000);
    assert_eq!(data.sum, values.iter().sum::<f64>() as u64);
    for p in [50.0, 90.0, 95.0, 99.0] {
        let got = data.percentile(p);
        let want = percentile_sorted(&values, p);
        let rel = (got - want).abs() / want;
        assert!(
            rel <= 0.25,
            "p{p}: bucketed {got:.0} vs oracle {want:.0} (rel err {rel:.3} > 0.25)"
        );
    }
    // The exposition folds the same merged data: the unlabeled family
    // ends with an exact _count sample.
    let text = obs::snapshot().to_prometheus();
    assert!(text.contains("# TYPE obs_itest_percentile_ns histogram"));
    assert!(text.contains("obs_itest_percentile_ns_count 10000"));
}

#[test]
fn concurrent_recording_merges_exactly() {
    obs::set_enabled(true);
    let c = obs::counter("obs_itest_concurrent_total");
    let h = obs::histogram("obs_itest_concurrent_ns");
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let c = c.clone();
            let h = h.clone();
            s.spawn(move || {
                for i in 0..PER_THREAD {
                    c.inc();
                    h.record(t * PER_THREAD + i);
                }
            });
        }
    });
    assert_eq!(c.value(), THREADS * PER_THREAD, "counter shards fold exactly");
    let data = h.merged();
    assert_eq!(data.count, THREADS * PER_THREAD);
    // Sum of 0..80000 — exact, independent of which shard each thread
    // landed on.
    let n = THREADS * PER_THREAD;
    assert_eq!(data.sum, n * (n - 1) / 2, "histogram shards fold exactly");
}

#[test]
fn prometheus_exposition_escapes_label_values() {
    obs::set_enabled(true);
    let raw = "a\\b\"c\nd";
    obs::counter_with("obs_itest_escaped_total", &[("path", raw)]).inc();
    let series = obs::series("obs_itest_escaped_total", &[("path", raw)]);
    // Backslash, quote, and newline all render escaped, so the series
    // stays a single well-formed exposition line.
    assert_eq!(series, "obs_itest_escaped_total{path=\"a\\\\b\\\"c\\nd\"}");
    let snap = obs::snapshot();
    assert_eq!(snap.counter(&series), Some(1));
    let text = snap.to_prometheus();
    assert!(text.contains("# TYPE obs_itest_escaped_total counter"));
    assert!(text.contains(&format!("{series} 1")));
}

#[test]
fn serve_shed_counters_match_typed_errors() {
    obs::set_enabled(true);
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let before = obs::snapshot();

    // Overloaded: queue_cap(1), second submit sheds synchronously.
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder().queue_cap(1).build().unwrap(),
    )
    .unwrap();
    let stream = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 64,
            deadline: None,
        })
        .unwrap();
    let err = server
        .submit_generate(GenerateRequest {
            prompt: problems[1].prompt.clone(),
            max_tokens: 1,
            deadline: None,
        })
        .map(|_| ())
        .unwrap_err();
    assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::Overloaded));
    stream.wait().unwrap();
    drop(server);

    // DeadlineExceeded: an already-expired deadline.
    let server =
        Server::start(Backend::Packed(Box::new(pm.clone())), ServerConfig::default()).unwrap();
    let err = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 8,
            deadline: Some(std::time::Duration::from_nanos(1)),
        })
        .unwrap()
        .wait()
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<ServeError>(),
        Some(&ServeError::DeadlineExceeded)
    );
    drop(server);

    // KvExhausted: a footprint the one-block arena can never hold.
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .kv_block_positions(4)
            .kv_blocks(1)
            .build()
            .unwrap(),
    )
    .unwrap();
    let err = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 16,
            deadline: None,
        })
        .unwrap()
        .wait()
        .unwrap_err();
    assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::KvExhausted));
    drop(server);

    // Invalid ×2: empty prompt, out-of-vocab token.
    let vocab = pm.config.vocab;
    let server = Server::start(Backend::Packed(Box::new(pm)), ServerConfig::default()).unwrap();
    for bad in [Vec::new(), vec![vocab + 5]] {
        let err = server
            .submit_generate(GenerateRequest {
                prompt: bad,
                max_tokens: 4,
                deadline: None,
            })
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::Invalid(_))
        ));
    }
    drop(server);

    // The labeled series sum to exactly the typed errors observed
    // above — no other test in this binary sheds.
    let after = obs::snapshot();
    let delta = |reason: &str| shed_count(&after, reason) - shed_count(&before, reason);
    assert_eq!(delta("overloaded"), 1);
    assert_eq!(delta("deadline"), 1);
    assert_eq!(delta("kv_exhausted"), 1);
    assert_eq!(delta("invalid"), 2);
    assert_eq!(delta("unsupported"), 0);
    assert_eq!(delta("internal"), 0);
}

#[test]
fn continuous_batching_stays_bit_identical_with_telemetry_on() {
    obs::set_enabled(true);
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let before = obs::snapshot();
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .workers(2)
            .kv_block_positions(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    let prompts: Vec<Vec<usize>> = problems.iter().take(6).map(|p| p.prompt.clone()).collect();
    let streams: Vec<_> = prompts
        .iter()
        .map(|p| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.clone(),
                    max_tokens: 6,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();
    let mut total = 0u64;
    for (p, s) in prompts.iter().zip(streams) {
        let done = s.wait().unwrap();
        assert_eq!(
            done.tokens,
            packed_oracle(&pm, p, 6),
            "telemetry recording must not perturb generation"
        );
        total += done.tokens.len() as u64;
    }
    assert_eq!(server.kv_blocks_in_use(), 0, "all arena blocks returned");

    // The serving series moved by at least this test's traffic (other
    // tests in this binary may add to them concurrently).
    let after = obs::snapshot();
    let tokens = counter_of(&after, obs::names::SERVE_TOKENS_TOTAL)
        - counter_of(&before, obs::names::SERVE_TOKENS_TOTAL);
    assert!(tokens >= total, "token counter undercounted: {tokens} < {total}");
    let admissions = counter_of(&after, obs::names::SERVE_ADMISSIONS_TOTAL)
        - counter_of(&before, obs::names::SERVE_ADMISSIONS_TOTAL);
    assert!(admissions >= prompts.len() as u64);
    let ttft = after
        .hist(obs::names::SERVE_TTFT_NS)
        .expect("ttft histogram registered by the serve loop");
    assert!(ttft.count >= prompts.len() as u64);
}
