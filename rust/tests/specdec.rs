//! Property suite for self-speculative decoding (DESIGN.md §11).
//!
//! The contract under test: speculative decoding is a pure *speed*
//! transformation — its output must be **bit-for-bit identical** to
//! target-only greedy decoding, across
//!
//! * draft widths {INT2, INT4} × draft lengths k ∈ {1, 2, 4, 8},
//! * both CPU target engines (packed INT8 and the f32 reference),
//! * owned and arena-paged decode states,
//! * mid-stream rollbacks (the INT2 draft genuinely diverges), and
//! * the continuous-batching server with mid-step admission.
//!
//! Resource hygiene rides along: every speculative session rents two
//! K/V states from the same arena and must return both.

use std::sync::Arc;

use splitquant::coordinator::server::{
    Backend, GenerateRequest, ServeError, Server, ServerConfig, TokenEvent,
};
use splitquant::data::{generate_problems, FactWorld, McqProblem};
use splitquant::model::decode::{DecodeState, KvArena};
use splitquant::model::forward::{generate_greedy, Workspace};
use splitquant::model::packed::PackedModel;
use splitquant::model::quantized::{quantize_model, Method};
use splitquant::model::specdec::{SpecConfig, SpecDecoder, SpecStats};
use splitquant::model::{Checkpoint, PicoLlamaConfig};
use splitquant::quant::Bits;
use splitquant::split::SplitConfig;

/// Outlier-amplified checkpoint over the fact-world vocab: the
/// amplified tails make the low-bit drafts *imperfect* (so acceptance
/// is partial and rollbacks actually execute) without being useless.
fn setup() -> (Checkpoint, Vec<McqProblem>) {
    let world = FactWorld::generate(16, 4, 8, 1);
    let mut cfg = PicoLlamaConfig::test();
    cfg.vocab = world.vocab_size();
    let mut ck = Checkpoint::random_init(&cfg, 7);
    ck.amplify_outliers(0.002, 8.0, 11);
    let problems = generate_problems(&world, 12, 5);
    (ck, problems)
}

fn packed_target(ck: &Checkpoint) -> PackedModel {
    let qm = quantize_model(ck, Bits::Int8, &Method::SplitQuant(SplitConfig::default())).unwrap();
    PackedModel::from_qmodel(&qm).unwrap()
}

fn draft_packed(ck: &Checkpoint, bits: Bits) -> PackedModel {
    let qm = quantize_model(ck, bits, &Method::SplitQuant(SplitConfig::default())).unwrap();
    PackedModel::from_qmodel(&qm).unwrap()
}

/// Sequential greedy oracle on the packed target.
fn packed_oracle(pm: &PackedModel, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let mut ws = Workspace::new(&pm.config, pm.config.max_seq);
    let mut scratch = pm.prewarmed_scratch();
    let mut state = DecodeState::new(&pm.config);
    pm.generate_greedy(prompt, n_new, &mut ws, &mut scratch, &mut state)
        .unwrap()
}

#[test]
fn speculative_matches_plain_greedy_across_widths_k_and_engines() {
    let (ck, problems) = setup();
    let target = packed_target(&ck);
    let cfg = &ck.config;
    let mut ws = Workspace::new(cfg, cfg.max_seq);
    let mut tscratch = target.prewarmed_scratch();
    let prompts: Vec<Vec<usize>> = problems.iter().take(3).map(|p| p.prompt.clone()).collect();
    for bits in [Bits::Int2, Bits::Int4] {
        let mut stats = SpecStats::default();
        for k in [1usize, 2, 4, 8] {
            let dec = SpecDecoder::from_checkpoint(&ck, bits, SpecConfig::fixed(k)).unwrap();
            let mut dscratch = dec.draft_model().prewarmed_scratch();
            for p in &prompts {
                // Budget past the context edge: exercises the max_seq
                // clamp inside the speculative loop too.
                let n_new = cfg.max_seq;
                let mut st = DecodeState::new(cfg);
                let want = target
                    .generate_greedy(p, n_new, &mut ws, &mut tscratch, &mut st)
                    .unwrap();
                let mut ts = DecodeState::new(cfg);
                let mut ds = DecodeState::new(cfg);
                let (got, s) = dec
                    .generate_packed(
                        &target,
                        p,
                        n_new,
                        &mut ws,
                        &mut tscratch,
                        &mut dscratch,
                        &mut ts,
                        &mut ds,
                    )
                    .unwrap();
                assert_eq!(got, want, "packed target, {bits:?} draft, k={k}");
                assert_eq!(s.emitted as usize, got.len());
                stats.merge(&s);

                let want_ref = generate_greedy(&ck, p, n_new, &mut ws).unwrap();
                let mut ts = DecodeState::new(cfg);
                let mut ds = DecodeState::new(cfg);
                let (got_ref, s) = dec
                    .generate_reference(&ck, p, n_new, &mut ws, &mut dscratch, &mut ts, &mut ds)
                    .unwrap();
                assert_eq!(got_ref, want_ref, "reference target, {bits:?} draft, k={k}");
                stats.merge(&s);
            }
        }
        assert!(stats.drafted > 0, "{bits:?}: drafts must have been proposed");
        assert!(stats.accepted <= stats.drafted);
        if bits == Bits::Int2 {
            // The INT2 draft must genuinely diverge from the target
            // mid-stream — otherwise this suite never exercises the
            // rollback path it claims to test.
            assert!(
                stats.accepted < stats.drafted,
                "expected partial acceptance (mid-stream rollbacks) with an INT2 draft"
            );
        }
    }
}

#[test]
fn speculative_on_paged_states_matches_owned_and_returns_blocks() {
    let (ck, problems) = setup();
    let target = packed_target(&ck);
    let cfg = &ck.config;
    let blocks_per_state = cfg.max_seq.div_ceil(4);
    let arena = Arc::new(KvArena::new(cfg, 4, 2 * blocks_per_state));
    let dec = SpecDecoder::from_checkpoint(&ck, Bits::Int4, SpecConfig::default()).unwrap();
    let mut ws = Workspace::new(cfg, cfg.max_seq);
    let mut tscratch = target.prewarmed_scratch();
    let mut dscratch = dec.draft_model().prewarmed_scratch();
    for p in problems.iter().take(3).map(|p| &p.prompt) {
        let want = packed_oracle(&target, p, 10);
        {
            let mut ts = DecodeState::paged(cfg, Arc::clone(&arena));
            let mut ds = DecodeState::paged(cfg, Arc::clone(&arena));
            let (got, _) = dec
                .generate_packed(&target, p, 10, &mut ws, &mut tscratch, &mut dscratch, &mut ts, &mut ds)
                .unwrap();
            assert_eq!(got, want, "paged speculative diverged from owned oracle");
            assert!(arena.blocks_in_use() > 0, "both states rent from the arena");
        }
        // Dropping target + draft states returns every block — the
        // arena is exactly balanced between decodes.
        assert_eq!(arena.blocks_in_use(), 0, "leaked arena blocks");
    }
}

/// Mid-step admission against a speculative server: the first stream
/// is already decoding when the rest are submitted, so later sessions
/// join a continuous batch whose members sit at different speculative
/// offsets. Every stream must still match the sequential oracle and
/// emit strictly in-order token indices.
fn assert_spec_server_matches_oracle(
    server: &Server,
    prompts: &[Vec<usize>],
    budgets: &[usize],
    oracle: impl Fn(&[usize], usize) -> Vec<usize>,
) {
    let first = server
        .submit_generate(GenerateRequest {
            prompt: prompts[0].clone(),
            max_tokens: budgets[0],
            deadline: None,
        })
        .unwrap();
    let first_event = first.recv().expect("first stream yields an event");
    assert!(matches!(first_event, TokenEvent::Token { index: 0, .. }));
    let rest: Vec<_> = prompts
        .iter()
        .zip(budgets)
        .skip(1)
        .map(|(p, &n)| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.clone(),
                    max_tokens: n,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();
    let mut first_tokens = match first_event {
        TokenEvent::Token { token, .. } => vec![token],
        _ => unreachable!(),
    };
    for ev in first.iter() {
        match ev {
            TokenEvent::Token { index, token } => {
                assert_eq!(index, first_tokens.len(), "in-order multi-token emission");
                first_tokens.push(token);
            }
            TokenEvent::Done(resp) => {
                assert_eq!(resp.tokens, first_tokens, "Done echoes the streamed tokens")
            }
            TokenEvent::Error(e) => panic!("stream 0 failed: {e}"),
        }
    }
    assert_eq!(first_tokens, oracle(&prompts[0], budgets[0]));
    for (i, s) in rest.into_iter().enumerate() {
        let done = s.wait().unwrap();
        assert_eq!(
            done.tokens,
            oracle(&prompts[i + 1], budgets[i + 1]),
            "speculative stream {} diverged from sequential greedy",
            i + 1
        );
    }
    assert_eq!(server.kv_blocks_in_use(), 0, "target AND draft blocks returned");
}

fn gen_inputs(problems: &[McqProblem], cfg: &PicoLlamaConfig) -> (Vec<Vec<usize>>, Vec<usize>) {
    let prompts: Vec<Vec<usize>> = problems.iter().take(6).map(|p| p.prompt.clone()).collect();
    let budgets: Vec<usize> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| match i % 3 {
            0 => 3 + i,
            1 => cfg.max_seq - p.len(), // exactly to the context edge
            _ => cfg.max_seq,           // clamped by max_seq mid-flight
        })
        .collect();
    (prompts, budgets)
}

#[test]
fn speculative_server_matches_sequential_greedy_packed_target() {
    let (ck, problems) = setup();
    let target = packed_target(&ck);
    let (prompts, budgets) = gen_inputs(&problems, &target.config);
    for bits in [Bits::Int2, Bits::Int4] {
        let draft = Arc::new(draft_packed(&ck, bits));
        let server = Server::start(
            Backend::Packed(Box::new(target.clone())),
            ServerConfig::builder()
                .workers(4)
                .kv_block_positions(4)
                .draft(Some(draft))
                .draft_k(4)
                .build()
                .unwrap(),
        )
        .unwrap();
        assert_spec_server_matches_oracle(&server, &prompts, &budgets, |p, n| {
            packed_oracle(&target, p, n)
        });
    }
}

#[test]
fn speculative_server_matches_sequential_greedy_reference_target() {
    let (ck, problems) = setup();
    let (prompts, budgets) = gen_inputs(&problems, &ck.config);
    let draft = Arc::new(draft_packed(&ck, Bits::Int4));
    let server = Server::start(
        Backend::Reference(Box::new(ck.clone())),
        ServerConfig::builder()
            .workers(4)
            .kv_block_positions(4)
            .draft(Some(draft))
            .draft_k(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_spec_server_matches_oracle(&server, &prompts, &budgets, |p, n| {
        let mut ws = Workspace::new(&ck.config, ck.config.max_seq);
        generate_greedy(&ck, p, n, &mut ws).unwrap()
    });
}

#[test]
fn speculative_sessions_reserve_double_and_shed_when_impossible() {
    let (ck, problems) = setup();
    let target = packed_target(&ck);
    let cfg = target.config.clone();
    let blocks_per_state = cfg.max_seq.div_ceil(4); // 8 with max_seq=32
    let draft = Arc::new(draft_packed(&ck, Bits::Int4));
    // Enough blocks for ONE full-context state but not two: a plain
    // server admits this request, a speculative one must shed it with
    // the typed KvExhausted (its worst case needs target + draft).
    let arena_blocks = blocks_per_state + 1;
    let plain = Server::start(
        Backend::Packed(Box::new(target.clone())),
        ServerConfig::builder()
            .kv_block_positions(4)
            .kv_blocks(arena_blocks)
            .build()
            .unwrap(),
    )
    .unwrap();
    let spec = Server::start(
        Backend::Packed(Box::new(target.clone())),
        ServerConfig::builder()
            .kv_block_positions(4)
            .kv_blocks(arena_blocks)
            .draft(Some(draft))
            .build()
            .unwrap(),
    )
    .unwrap();
    let req = || GenerateRequest {
        prompt: problems[0].prompt.clone(),
        max_tokens: cfg.max_seq, // worst case: the full context
        deadline: None,
    };
    let ok = plain.submit_generate(req()).unwrap().wait().unwrap();
    assert!(!ok.tokens.is_empty());
    let err = spec.submit_generate(req()).unwrap().wait().unwrap_err();
    assert_eq!(
        err.downcast_ref::<ServeError>(),
        Some(&ServeError::KvExhausted),
        "a speculative session's worst case is two full K/V states"
    );
    // A request whose doubled footprint fits is still served — and
    // bit-identically.
    let short: Vec<usize> = problems[0].prompt.iter().take(4).copied().collect();
    let small = GenerateRequest {
        prompt: short.clone(),
        max_tokens: 2,
        deadline: None,
    };
    let done = spec.submit_generate(small).unwrap().wait().unwrap();
    assert_eq!(done.tokens, packed_oracle(&target, &short, 2));
    assert_eq!(spec.kv_blocks_in_use(), 0);
}
