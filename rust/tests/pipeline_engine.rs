//! Property tests for the layer-pipeline engine: for ANY worker count,
//! window size, model shape, method and bit width, the engine's output
//! must be bit-identical to the sequential reference
//! (`quantize_model`) — the core determinism contract of the tentpole.
//!
//! Generators are hand-rolled over the crate's deterministic PRNG
//! (proptest is unavailable offline); failures print the seed.

use splitquant::model::quantized::{quantize_model, Method, QuantParam, QuantizedModel};
use splitquant::model::{Checkpoint, PicoLlamaConfig};
use splitquant::pipeline::{Engine, PipelineConfig};
use splitquant::quant::Bits;
use splitquant::split::{SplitConfig, Strategy};
use splitquant::util::rng::Rng;

/// A random *valid* model shape: d_model divisible by n_heads, n_heads
/// divisible by n_kv_heads, even head_dim — so every trial has a
/// different layer set (count and sizes).
fn random_config(seed: u64) -> PicoLlamaConfig {
    let mut r = Rng::new(seed);
    let head_dim = [4usize, 8][r.below(2)];
    let n_kv_heads = 1 + r.below(2); // 1..=2
    let groups = 1 + r.below(3); // 1..=3
    let n_heads = n_kv_heads * groups;
    PicoLlamaConfig {
        vocab: 32 + r.below(64),
        d_model: n_heads * head_dim,
        n_layers: 1 + r.below(3),
        n_heads,
        n_kv_heads,
        d_ff: 16 + 8 * r.below(6),
        max_seq: 32,
        rope_theta: 10_000.0,
        norm_eps: 1e-5,
        tie_embeddings: r.below(2) == 0,
    }
}

fn random_checkpoint(seed: u64) -> Checkpoint {
    let cfg = random_config(seed);
    cfg.validate().expect("generator must produce valid configs");
    let mut ck = Checkpoint::random_init(&cfg, seed ^ 0xbeef);
    if seed % 2 == 0 {
        ck.amplify_outliers(0.003, 12.0, seed + 1);
    }
    ck
}

fn assert_bit_identical(a: &QuantizedModel, b: &QuantizedModel, ctx: &str) {
    assert_eq!(a.method_name, b.method_name, "{ctx}");
    assert_eq!(a.packed_bytes(), b.packed_bytes(), "{ctx}");
    assert_eq!(a.stored_values(), b.stored_values(), "{ctx}");
    assert_eq!(a.linears.len(), b.linears.len(), "{ctx}");
    // Plane-level comparison: integer levels and params, not just the
    // dequantized view.
    for (name, qa) in &a.linears {
        let qb = b.linears.get(name).unwrap_or_else(|| panic!("{ctx}: missing {name}"));
        match (qa, qb) {
            (QuantParam::Plain(x), QuantParam::Plain(y)) => {
                assert_eq!(x.plane.data(), y.plane.data(), "{ctx} {name}");
                assert_eq!(x.params, y.params, "{ctx} {name}");
            }
            (QuantParam::Split(x), QuantParam::Split(y)) => {
                assert_eq!(x.k(), y.k(), "{ctx} {name}");
                for (pa, pb) in x.planes.iter().zip(&y.planes) {
                    assert_eq!(pa.plane.data(), pb.plane.data(), "{ctx} {name}");
                    assert_eq!(pa.params, pb.params, "{ctx} {name}");
                }
            }
            (
                QuantParam::OcsEffective { effective: x, packed_len: lx },
                QuantParam::OcsEffective { effective: y, packed_len: ly },
            ) => {
                assert_eq!(x.data(), y.data(), "{ctx} {name}");
                assert_eq!(lx, ly, "{ctx} {name}");
            }
            _ => panic!("{ctx} {name}: variant mismatch"),
        }
    }
    assert_eq!(
        a.embedding.plane.data(),
        b.embedding.plane.data(),
        "{ctx} embedding"
    );
    assert_eq!(a.embedding.params, b.embedding.params, "{ctx} embedding params");
    for (name, t) in &a.fp_tensors {
        assert_eq!(b.fp_tensors.get(name).unwrap(), t, "{ctx} {name}");
    }
}

#[test]
fn prop_pipeline_identical_to_sequential_over_random_layer_sets() {
    for seed in 0..12u64 {
        let ck = random_checkpoint(seed);
        let mut r = Rng::new(seed + 500);
        let method = match r.below(3) {
            0 => Method::Baseline,
            1 => Method::SplitQuant(SplitConfig::default()),
            _ => Method::Ocs { expand_ratio: 0.04 },
        };
        let bits = [Bits::Int2, Bits::Int4, Bits::Int8][r.below(3)];
        let reference = quantize_model(&ck, bits, &method).unwrap();
        for threads in [1usize, 2, 5] {
            let engine = Engine::new(threads);
            let qm = engine.quantize_model(&ck, bits, &method).unwrap();
            assert_bit_identical(
                &reference,
                &qm,
                &format!("seed {seed} threads {threads} {bits:?}"),
            );
        }
    }
}

#[test]
fn prop_pipeline_identical_across_window_sizes_and_strategies() {
    for seed in 20..26u64 {
        let ck = random_checkpoint(seed);
        for strategy in [Strategy::MaskedSum, Strategy::RowWise] {
            let method = Method::SplitQuant(SplitConfig {
                strategy,
                ..Default::default()
            });
            let reference = quantize_model(&ck, Bits::Int4, &method).unwrap();
            for window_per_worker in [1usize, 4] {
                let engine = Engine::with_config(PipelineConfig {
                    threads: 3,
                    window_per_worker,
                    ..Default::default()
                });
                let qm = engine.quantize_model(&ck, Bits::Int4, &method).unwrap();
                assert_bit_identical(
                    &reference,
                    &qm,
                    &format!("seed {seed} {strategy:?} window/worker {window_per_worker}"),
                );
            }
        }
    }
}

#[test]
fn pipeline_is_deterministic_across_repeated_runs() {
    let ck = random_checkpoint(99);
    let method = Method::SplitQuant(SplitConfig::default());
    let engine = Engine::new(4);
    let first = engine.quantize_model(&ck, Bits::Int4, &method).unwrap();
    for run in 0..3 {
        let again = engine.quantize_model(&ck, Bits::Int4, &method).unwrap();
        assert_bit_identical(&first, &again, &format!("run {run}"));
    }
}

#[test]
fn threads_exceeding_unit_count_matches_sequential() {
    let ck = random_checkpoint(7);
    let method = Method::SplitQuant(SplitConfig::default());
    let reference = quantize_model(&ck, Bits::Int4, &method).unwrap();
    // Far more workers than the model has parameters.
    let engine = Engine::new(64);
    let qm = engine.quantize_model(&ck, Bits::Int4, &method).unwrap();
    assert_bit_identical(&reference, &qm, "threads=64");
}

#[test]
fn engine_panic_propagates_to_caller() {
    let engine = Engine::new(3);
    let items: Vec<usize> = (0..30).collect();
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.run_ordered(&items, |_, &v| {
            if v == 17 {
                panic!("unit 17 failed");
            }
            v
        })
    }));
    assert!(r.is_err(), "worker panic must propagate out of the engine");
    // The engine survives and stays correct afterwards.
    let ok = engine.run_ordered(&items, |_, &v| v + 1);
    assert_eq!(ok, (1..=30).collect::<Vec<_>>());
}

#[test]
fn missing_tensor_surfaces_as_error_not_panic() {
    let mut ck = random_checkpoint(3);
    let name = ck
        .tensors
        .keys()
        .find(|k| k.contains("attn"))
        .unwrap()
        .clone();
    ck.tensors.remove(&name);
    let engine = Engine::new(4);
    let err = engine
        .quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
        .unwrap_err();
    assert!(err.to_string().contains("missing tensor"), "{err}");
}
