//! Property sweeps for the resumable decode-state forward (hand-rolled
//! generators over the crate's deterministic PRNG — proptest is
//! unavailable offline).
//!
//! Pinned invariants, across random model shapes, token sequences and
//! split points on **both** execution engines:
//!
//! 1. `forward_extend` chunking reproduces the whole-sequence forward
//!    exactly (same loop, same FP operation order).
//! 2. Prefix-reuse MCQ scoring (one prompt pass + per-option extension
//!    with rollback) matches the seed full-recompute path within 1e-4
//!    and agrees on every chosen option.
//! 3. A pool-sharded server batch (4 workers + prefix cache) returns
//!    results identical to the sequential executor (1 worker, no
//!    cache).

use splitquant::coordinator::server::{Backend, Server, ServerConfig};
use splitquant::data::McqProblem;
use splitquant::eval::{
    score_problem, score_problem_full, score_problem_packed, score_problem_packed_full,
    ScoreBuffers,
};
use splitquant::model::decode::{DecodeState, KvArena};
use splitquant::model::forward::{forward, forward_extend_ck, Workspace};
use splitquant::model::packed::PackedModel;
use splitquant::model::quantized::{quantize_model, Method};
use splitquant::model::{Checkpoint, PicoLlamaConfig};
use splitquant::quant::Bits;
use splitquant::split::SplitConfig;
use splitquant::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

const TRIALS: u64 = 12;

/// Random small-but-varied model config (GQA shapes included).
fn random_config(r: &mut Rng) -> PicoLlamaConfig {
    let n_kv_heads = 1 + r.below(2); // 1 or 2
    let n_heads = n_kv_heads * (1 + r.below(3)); // ×1..3
    let head_dim = 2 * (1 + r.below(4)); // even, 2..8
    PicoLlamaConfig {
        vocab: 32 + r.below(64),
        d_model: n_heads * head_dim,
        n_layers: 1 + r.below(3),
        n_heads,
        n_kv_heads,
        d_ff: 8 + r.below(48),
        max_seq: 32,
        rope_theta: 10_000.0,
        norm_eps: 1e-5,
        tie_embeddings: true,
    }
}

fn random_tokens(r: &mut Rng, cfg: &PicoLlamaConfig, len: usize) -> Vec<usize> {
    (0..len).map(|_| r.below(cfg.vocab)).collect()
}

fn random_problem(r: &mut Rng, cfg: &PicoLlamaConfig) -> McqProblem {
    let plen = 1 + r.below(6);
    let n_opts = 2 + r.below(4);
    let max_opt = (cfg.max_seq - plen).min(4);
    McqProblem {
        prompt: random_tokens(r, cfg, plen),
        options: (0..n_opts)
            .map(|_| random_tokens(r, cfg, 1 + r.below(max_opt)))
            .collect(),
        correct: r.below(n_opts),
    }
}

#[test]
fn prop_extend_chunking_matches_full_forward_both_engines() {
    for seed in 0..TRIALS {
        let mut r = Rng::new(1000 + seed);
        let cfg = random_config(&mut r);
        let mut ck = Checkpoint::random_init(&cfg, seed);
        ck.amplify_outliers(0.005, 6.0, seed);
        let len = 2 + r.below(10);
        let toks = random_tokens(&mut r, &cfg, len);
        let split = 1 + r.below(len - 1);
        let mut ws = Workspace::new(&cfg, cfg.max_seq);

        // Reference engine: exact equality (same loop, same FP order).
        let full = forward(&ck, &toks, &mut ws).unwrap();
        let mut state = DecodeState::new(&cfg);
        let head = forward_extend_ck(&ck, &toks[..split], 0, &mut ws, &mut state).unwrap();
        let tail = forward_extend_ck(&ck, &toks[split..], split, &mut ws, &mut state).unwrap();
        for t in 0..len {
            let got = if t < split { head.row(t) } else { tail.row(t - split) };
            assert_eq!(got, full.row(t), "seed {seed} split {split} row {t} (reference)");
        }

        // Packed engine: same invariant on bit-packed weights.
        let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
            .unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let mut scratch = pm.prewarmed_scratch();
        let pfull = pm.forward(&toks, &mut ws).unwrap();
        let mut pstate = DecodeState::new(&cfg);
        let phead = pm
            .forward_extend(&toks[..split], 0, &mut ws, &mut scratch, &mut pstate)
            .unwrap();
        let ptail = pm
            .forward_extend(&toks[split..], split, &mut ws, &mut scratch, &mut pstate)
            .unwrap();
        for t in 0..len {
            let got = if t < split { phead.row(t) } else { ptail.row(t - split) };
            assert_eq!(got, pfull.row(t), "seed {seed} split {split} row {t} (packed)");
        }
    }
}

#[test]
fn prop_prefix_reuse_scoring_matches_full_recompute_both_engines() {
    for seed in 0..TRIALS {
        let mut r = Rng::new(2000 + seed);
        let cfg = random_config(&mut r);
        let mut ck = Checkpoint::random_init(&cfg, 7 * seed + 1);
        ck.amplify_outliers(0.005, 6.0, seed);
        let qm = quantize_model(&ck, Bits::Int8, &Method::SplitQuant(SplitConfig::default()))
            .unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let eff = qm.effective_checkpoint();

        let mut ref_bufs = ScoreBuffers::new(&cfg, cfg.max_seq);
        let mut packed_bufs = ScoreBuffers::for_packed(&pm, cfg.max_seq);
        let mut ws = Workspace::new(&cfg, cfg.max_seq);
        let mut scratch = pm.prewarmed_scratch();
        for _ in 0..4 {
            let p = random_problem(&mut r, &cfg);

            let fast = score_problem(&eff, &p, &mut ref_bufs).unwrap();
            let full = score_problem_full(&eff, &p, &mut ws).unwrap();
            assert_eq!(fast.chosen, full.chosen, "seed {seed}: choice must agree");
            for (a, b) in fast.logprobs.iter().zip(&full.logprobs) {
                assert!((a - b).abs() < 1e-4, "seed {seed}: {a} vs {b} (reference)");
            }

            let pfast = score_problem_packed(&pm, &p, &mut packed_bufs).unwrap();
            let pfull = score_problem_packed_full(&pm, &p, &mut ws, &mut scratch).unwrap();
            assert_eq!(pfast.chosen, pfull.chosen, "seed {seed}: packed choice must agree");
            for (a, b) in pfast.logprobs.iter().zip(&pfull.logprobs) {
                assert!((a - b).abs() < 1e-4, "seed {seed}: {a} vs {b} (packed)");
            }
        }
    }
}

#[test]
fn prop_speculative_rollback_cycles_balance_the_arena() {
    // Speculative decoding's hot pattern on an arena-backed state:
    // extend a chunk, accept a prefix, truncate back, re-extend — over
    // and over. The arena must stay *exactly* balanced: the up-front
    // reservation rents once, truncate/re-extend never rents or leaks,
    // and `kv_reservation_failures_total` stays 0 below capacity.
    splitquant::obs::set_enabled(true);
    let reservation_failures = || {
        splitquant::obs::snapshot()
            .counter(splitquant::obs::names::KV_RESERVATION_FAILURES)
            .unwrap_or(0)
    };
    for seed in 0..6u64 {
        let mut r = Rng::new(4000 + seed);
        let cfg = random_config(&mut r);
        let mut ck = Checkpoint::random_init(&cfg, 17 * seed + 3);
        ck.amplify_outliers(0.005, 6.0, seed);
        let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
            .unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let mut ws = Workspace::new(&cfg, cfg.max_seq);
        let mut scratch = pm.prewarmed_scratch();
        let block_positions = 4;
        let per_state = cfg.max_seq.div_ceil(block_positions);
        let arena = Arc::new(KvArena::new(&cfg, block_positions, 2 * per_state));
        let f0 = reservation_failures();

        let len = 12 + r.below(cfg.max_seq - 12);
        let toks = random_tokens(&mut r, &cfg, len);
        // Oracle rows: the whole-sequence forward, no rollbacks.
        let full = pm.forward(&toks, &mut ws).unwrap();
        {
            let mut state = DecodeState::paged(&cfg, Arc::clone(&arena));
            state.reserve(cfg.max_seq).unwrap();
            let held = state.blocks_held();
            assert_eq!(arena.blocks_in_use(), held, "reservation rents exactly once");
            for cycle in 0..24 {
                let cached = state.len();
                if cached >= len {
                    state.truncate(cached / 2);
                    continue;
                }
                // Extend a speculative chunk and verify every row
                // against the rollback-free oracle.
                let c = 1 + r.below((len - cached).min(4));
                let logits = pm
                    .forward_extend(&toks[cached..cached + c], cached, &mut ws, &mut scratch, &mut state)
                    .unwrap();
                for i in 0..c {
                    assert_eq!(
                        logits.row(i),
                        full.row(cached + i),
                        "seed {seed} cycle {cycle}: re-extended row diverged after rollback"
                    );
                }
                // Snapshots copy to owned storage — they must not pin
                // or rent arena blocks.
                if cycle % 5 == 0 {
                    let snap = state.snapshot(state.len());
                    assert_eq!(snap.blocks_held(), 0);
                }
                // Accept a random prefix of the chunk, roll back the rest.
                let accepted = r.below(c + 1);
                state.truncate(cached + accepted);
                assert_eq!(
                    arena.blocks_in_use(),
                    held,
                    "seed {seed} cycle {cycle}: truncate/extend must not rent or return blocks"
                );
            }
            // A second full-context state (the speculative draft) still
            // fits: reservation is all-or-nothing and the first state
            // never over-rented.
            let mut draft = DecodeState::paged(&cfg, Arc::clone(&arena));
            draft.reserve(cfg.max_seq).unwrap();
            assert_eq!(arena.blocks_in_use(), 2 * per_state);
            assert_eq!(
                reservation_failures() - f0,
                0,
                "seed {seed}: no reservation may fail below capacity"
            );
            // Over capacity the typed failure fires, the counter ticks,
            // and the partial rental is kept (not leaked, not doubled).
            let mut third = DecodeState::paged(&cfg, Arc::clone(&arena));
            let err = third.reserve(1).unwrap_err();
            assert!(err.requested >= 1);
            assert_eq!(reservation_failures() - f0, 1);
        }
        // Dropping every state returns every block.
        assert_eq!(arena.blocks_in_use(), 0, "seed {seed}: leaked arena blocks");
    }
}

#[test]
fn prop_sharded_server_batch_matches_sequential_executor() {
    for seed in 0..4u64 {
        let mut r = Rng::new(3000 + seed);
        let cfg = random_config(&mut r);
        let ck = Checkpoint::random_init(&cfg, 13 * seed + 5);
        let qm = quantize_model(&ck, Bits::Int4, &Method::Baseline).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        // Duplicate some prompts so the prefix cache actually hits.
        let mut problems: Vec<McqProblem> =
            (0..10).map(|_| random_problem(&mut r, &cfg)).collect();
        for i in 0..5 {
            let mut dup = problems[i].clone();
            dup.correct = (dup.correct + 1) % dup.options.len();
            problems.push(dup);
        }

        let sharded = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig {
                max_wait: Duration::from_millis(50),
                max_batch: 32,
                workers: 4,
                prefix_cache: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let sequential = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                max_wait: Duration::from_millis(50),
                max_batch: 32,
                workers: 1,
                prefix_cache: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_a: Vec<_> = problems.iter().map(|p| sharded.submit(p.clone())).collect();
        let rx_b: Vec<_> = problems.iter().map(|p| sequential.submit(p.clone())).collect();
        for (i, (a, b)) in rx_a.into_iter().zip(rx_b).enumerate() {
            let a = a.recv().unwrap().unwrap();
            let b = b.recv().unwrap().unwrap();
            assert_eq!(
                a.result.logprobs, b.result.logprobs,
                "seed {seed} problem {i}: sharded vs sequential logprobs"
            );
            assert_eq!(a.result.chosen, b.result.chosen, "seed {seed} problem {i}");
        }
    }
}
