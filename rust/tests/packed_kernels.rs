//! Property tests for the packed-integer kernel engine: packed GEMV/GEMM
//! must match the dequantize-then-f32 oracle within tight tolerance
//! across bit widths, parameter kinds (plain per-tensor, plain
//! per-channel, split, OCS-dense), odd shapes, and degenerate
//! (empty-cluster) planes — plus a PackedForward vs reference-forward
//! end-to-end logit check.

use splitquant::kernels::{self, KernelScratch};
use splitquant::kmeans::Clustering1D;
use splitquant::model::forward::{self, Workspace};
use splitquant::model::packed::{pack_linear, PackedModel};
use splitquant::model::quantized::{quantize_model, Method, QuantParam};
use splitquant::model::{Checkpoint, PicoLlamaConfig};
use splitquant::quant::{self, Bits, QuantParams};
use splitquant::split::{split_quantize, QuantizedSplitLayer, SplitConfig, Strategy};
use splitquant::tensor::{matmul, Tensor, TensorI8};
use splitquant::util::rng::Rng;
use splitquant::util::stats::max_abs_diff;

/// LLM-like weights: mostly small values, a few large outliers (the
/// regime split layers exist for).
fn heavy_tensor(seed: u64, rows: usize, cols: usize) -> Tensor {
    let mut r = Rng::new(seed);
    let mut data: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32(0.0, 0.05)).collect();
    let n_out = (data.len() / 50).max(1);
    for _ in 0..n_out {
        let i = r.below(data.len());
        data[i] = r.uniform_in(1.0, 2.5) * if r.uniform() < 0.5 { -1.0 } else { 1.0 };
    }
    Tensor::new(&[rows, cols], data)
}

fn random_x(seed: u64, seq: usize, cols: usize) -> Tensor {
    let mut r = Rng::new(seed);
    let mut data = vec![0.0f32; seq * cols];
    r.fill_normal(&mut data, 0.0, 1.0);
    Tensor::new(&[seq, cols], data)
}

/// The oracle every kernel is held against: dequantize the parameter to
/// its effective f32 weight, then plain f32 matmul.
fn oracle(x: &Tensor, qp: &QuantParam) -> Tensor {
    matmul(x, &qp.effective().transpose())
}

fn assert_close(got: &[f32], want: &[f32], label: &str) {
    let scale = want.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1.0);
    let diff = max_abs_diff(got, want);
    assert!(
        diff < 1e-3 * scale as f64,
        "{label}: diff {diff} vs magnitude {scale}"
    );
}

#[test]
fn gemm_matches_oracle_across_bits_params_and_odd_shapes() {
    let mut scratch = KernelScratch::new();
    let mut seed = 100;
    for bits in [Bits::Int4, Bits::Int8] {
        for (rows, cols) in [(5usize, 7usize), (1, 9), (8, 1), (16, 33), (12, 64)] {
            seed += 1;
            let w = heavy_tensor(seed, rows, cols);
            let params: Vec<(&str, QuantParam)> = vec![
                ("plain", QuantParam::Plain(quant::quantize_per_tensor(&w, bits))),
                (
                    "per-channel",
                    QuantParam::Plain(quant::quantize_per_channel(&w, bits)),
                ),
                (
                    "split",
                    QuantParam::Split(split_quantize(&w, &SplitConfig::default(), bits)),
                ),
                (
                    "ocs-dense",
                    QuantParam::OcsEffective {
                        effective: w.clone(),
                        packed_len: 0,
                    },
                ),
            ];
            for (kind, qp) in &params {
                let label = format!("{bits:?} {rows}x{cols} {kind}");
                let lin = pack_linear(qp).unwrap_or_else(|e| panic!("{label}: {e}"));
                assert_eq!(lin.out_dim(), rows, "{label}");
                assert_eq!(lin.in_dim(), cols, "{label}");
                for seq in [1usize, 3] {
                    let x = random_x(seed * 10 + seq as u64, seq, cols);
                    let want = oracle(&x, qp);
                    let mut y = vec![0.0f32; seq * rows];
                    kernels::gemm(&mut y, x.data(), seq, &lin, &mut scratch);
                    assert_close(&y, want.data(), &format!("{label} seq={seq}"));
                }
                // gemv == gemm with seq 1.
                let x = random_x(seed * 31, 1, cols);
                let mut y1 = vec![0.0f32; rows];
                let mut yg = vec![0.0f32; rows];
                kernels::gemv(&mut y1, x.data(), &lin, &mut scratch);
                kernels::gemm(&mut yg, x.data(), 1, &lin, &mut scratch);
                assert_eq!(y1, yg, "{label} gemv vs gemm");
            }
        }
    }
}

#[test]
fn int2_planes_execute_too() {
    let mut scratch = KernelScratch::new();
    for (rows, cols) in [(4usize, 5usize), (9, 16)] {
        let w = heavy_tensor(7, rows, cols);
        let qp = QuantParam::Split(split_quantize(&w, &SplitConfig::default(), Bits::Int2));
        let lin = pack_linear(&qp).unwrap();
        let x = random_x(8, 2, cols);
        let want = oracle(&x, &qp);
        let mut y = vec![0.0f32; 2 * rows];
        kernels::gemm(&mut y, x.data(), 2, &lin, &mut scratch);
        assert_close(&y, want.data(), &format!("INT2 {rows}x{cols}"));
    }
}

#[test]
fn empty_cluster_plane_contributes_exactly_zero() {
    // A degenerate split layer whose second plane is all masked zeros
    // (an empty cluster: scale 1, zero-point 0, every level 0) must
    // produce bit-identical output to the single-plane layer.
    let w = heavy_tensor(21, 6, 10);
    for bits in [Bits::Int4, Bits::Int8] {
        let qa = quant::quantize_per_tensor(&w, bits);
        let zero_plane = splitquant::quant::QuantizedTensor {
            plane: TensorI8::zeros(&[6, 10]),
            granularity: splitquant::quant::Granularity::PerTensor,
            params: vec![QuantParams::from_range(bits, 0.0, 0.0)],
        };
        let clustering = Clustering1D {
            centroids: vec![0.0, 0.0],
            boundaries: vec![f64::INFINITY],
            inertia: 0.0,
            sizes: vec![w.len() as f64, 0.0],
            member_ranges: None,
        };
        let with_empty = QuantParam::Split(QuantizedSplitLayer {
            planes: vec![qa.clone(), zero_plane],
            clustering,
            strategy: Strategy::MaskedSum,
        });
        let single = QuantParam::Plain(qa.clone());
        let lin_a = pack_linear(&with_empty).unwrap();
        let lin_b = pack_linear(&single).unwrap();
        let x = random_x(22, 2, 10);
        let mut scratch = KernelScratch::new();
        let mut ya = vec![0.0f32; 2 * 6];
        let mut yb = vec![0.0f32; 2 * 6];
        kernels::gemm(&mut ya, x.data(), 2, &lin_a, &mut scratch);
        kernels::gemm(&mut yb, x.data(), 2, &lin_b, &mut scratch);
        assert_eq!(ya, yb, "{bits:?}: empty plane leaked");
    }
}

#[test]
fn int8_activation_kernel_within_quantization_tolerance() {
    let mut scratch = KernelScratch::new();
    let w = heavy_tensor(30, 24, 48);
    for bits in [Bits::Int4, Bits::Int8] {
        let qp = QuantParam::Split(split_quantize(&w, &SplitConfig::default(), bits));
        let lin = pack_linear(&qp).unwrap();
        let x = random_x(31, 3, 48);
        let mut exact = vec![0.0f32; 3 * 24];
        kernels::gemm(&mut exact, x.data(), 3, &lin, &mut scratch);
        let mut int = vec![0.0f32; 3 * 24];
        kernels::gemm_int8(&mut int, x.data(), 3, &lin, &mut scratch);
        let scale = exact.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6);
        assert!(
            max_abs_diff(&int, &exact) < 0.05 * scale as f64 + 1e-3,
            "{bits:?}: int path drifted {} vs magnitude {scale}",
            max_abs_diff(&int, &exact)
        );
    }
}

fn test_checkpoint() -> Checkpoint {
    let mut ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 55);
    ck.amplify_outliers(0.002, 12.0, 9);
    ck
}

#[test]
fn packed_forward_matches_reference_forward_end_to_end() {
    let ck = test_checkpoint();
    let toks: Vec<usize> = vec![1, 7, 23, 4, 2, 11];
    for bits in [Bits::Int4, Bits::Int8] {
        for method in [
            Method::Baseline,
            Method::SplitQuant(SplitConfig::default()),
            Method::Ocs { expand_ratio: 0.05 },
        ] {
            let qm = quantize_model(&ck, bits, &method).unwrap();
            let pm = PackedModel::from_qmodel(&qm).unwrap();
            let eff = qm.effective_checkpoint();
            let mut ws = Workspace::new(&ck.config, 16);
            let want = forward::forward(&eff, &toks, &mut ws).unwrap();
            let got = pm.forward(&toks, &mut ws).unwrap();
            assert_eq!(got.shape(), want.shape());
            assert_close(
                got.data(),
                want.data(),
                &format!("{bits:?}/{} logits", qm.method_name),
            );
        }
    }
}

#[test]
fn packed_scoring_agrees_with_reference_on_decided_problems() {
    let world = splitquant::data::FactWorld::generate(16, 4, 8, 3);
    let mut cfg = PicoLlamaConfig::test();
    cfg.vocab = world.vocab_size();
    let mut ck = Checkpoint::random_init(&cfg, 77);
    ck.amplify_outliers(0.002, 8.0, 2);
    let problems = splitquant::data::generate_problems(&world, 32, 5);
    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let eff = qm.effective_checkpoint();
    let mut ref_bufs = splitquant::eval::ScoreBuffers::new(&cfg, 16);
    let mut packed_bufs = splitquant::eval::ScoreBuffers::for_packed(&pm, 16);
    for p in &problems {
        let a = splitquant::eval::score_problem(&eff, p, &mut ref_bufs).unwrap();
        let b = splitquant::eval::score_problem_packed(&pm, p, &mut packed_bufs).unwrap();
        // Identical choices except at FP-noise-level ties.
        if a.chosen != b.chosen {
            assert!(a.margin() < 1e-4, "margin {} flipped", a.margin());
        }
        for (la, lb) in a.logprobs.iter().zip(&b.logprobs) {
            assert!((la - lb).abs() < 1e-3, "logprob {la} vs {lb}");
        }
    }
}

#[test]
fn packed_weight_traffic_under_half_of_f32_at_int4() {
    // The perf acceptance bound: at INT4 the packed path must touch
    // < 0.5x the weight bytes of the f32 path — even for k=3 split
    // layers (3/8 per linear), and ~1/8 for the baseline.
    let ck = test_checkpoint();
    let f32_bytes = ck.fp32_bytes() as f64;
    let split = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
        .unwrap();
    let base = quantize_model(&ck, Bits::Int4, &Method::Baseline).unwrap();
    let pm_split = PackedModel::from_qmodel(&split).unwrap();
    let pm_base = PackedModel::from_qmodel(&base).unwrap();
    let r_split = pm_split.weight_bytes_per_forward() as f64 / f32_bytes;
    let r_base = pm_base.weight_bytes_per_forward() as f64 / f32_bytes;
    assert!(r_split < 0.5, "split ratio {r_split}");
    assert!(r_base < 0.2, "baseline ratio {r_base}");
}
