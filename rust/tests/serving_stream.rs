//! Integration suite for the continuous-batching streaming generation
//! service (DESIGN.md §8):
//!
//! * **Bit-identity** — generations admitted *mid-step* into a running
//!   continuous batch must match sequential `generate_greedy` exactly,
//!   token for token, on both CPU engines. This is the core serving
//!   correctness contract: paged K/V + step-interleaved decoding must
//!   be invisible in the output.
//! * **Resource hygiene** — cancellation (dropping the stream) and
//!   completion both return every K/V block to the shared arena.
//! * **Typed overload behavior** — deadlines, queue caps, and
//!   impossible K/V footprints shed with a typed [`ServeError`], never
//!   by hanging.

use std::time::{Duration, Instant};

use splitquant::coordinator::server::{
    Backend, FinishReason, GenerateRequest, ServeError, Server, ServerConfig, TokenEvent,
};
use splitquant::data::{generate_problems, FactWorld, McqProblem};
use splitquant::model::decode::DecodeState;
use splitquant::model::forward::{generate_greedy, Workspace};
use splitquant::model::packed::PackedModel;
use splitquant::model::quantized::{quantize_model, Method, QuantizedModel};
use splitquant::model::{Checkpoint, PicoLlamaConfig};
use splitquant::quant::Bits;
use splitquant::split::SplitConfig;

fn setup() -> (QuantizedModel, Vec<McqProblem>) {
    let world = FactWorld::generate(16, 4, 8, 1);
    let mut cfg = PicoLlamaConfig::test();
    cfg.vocab = world.vocab_size();
    let ck = Checkpoint::random_init(&cfg, 7);
    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
    let problems = generate_problems(&world, 12, 5);
    (qm, problems)
}

/// Sequential greedy oracle on the packed engine (owned, contiguous
/// decode state — the pre-serving code path).
fn packed_oracle(pm: &PackedModel, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let mut ws = Workspace::new(&pm.config, pm.config.max_seq);
    let mut scratch = pm.prewarmed_scratch();
    let mut state = DecodeState::new(&pm.config);
    pm.generate_greedy(prompt, n_new, &mut ws, &mut scratch, &mut state)
        .unwrap()
}

/// Sequential greedy oracle on the reference engine.
fn reference_oracle(ck: &Checkpoint, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let mut ws = Workspace::new(&ck.config, ck.config.max_seq);
    generate_greedy(ck, prompt, n_new, &mut ws).unwrap()
}

/// Drive one server through a mid-step admission schedule and compare
/// every stream against its oracle: the first request starts decoding
/// alone, the rest are only submitted after its first token arrives —
/// i.e. they join a batch that is already mid-generation.
fn assert_continuous_matches_sequential(
    server: &Server,
    prompts: &[Vec<usize>],
    budgets: &[usize],
    oracle: impl Fn(&[usize], usize) -> Vec<usize>,
) {
    let first = server
        .submit_generate(GenerateRequest {
            prompt: prompts[0].clone(),
            max_tokens: budgets[0],
            deadline: None,
        })
        .unwrap();
    // Hold the first token so we know the batch is live before the
    // rest are admitted (true mid-step admission, not a cold start).
    let first_event = first.recv().expect("first stream yields an event");
    assert!(matches!(first_event, TokenEvent::Token { index: 0, .. }));
    let rest: Vec<_> = prompts
        .iter()
        .zip(budgets)
        .skip(1)
        .map(|(p, &n)| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.clone(),
                    max_tokens: n,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();

    // Drain the first stream manually (its first token is already out).
    let mut first_tokens = match first_event {
        TokenEvent::Token { token, .. } => vec![token],
        _ => unreachable!(),
    };
    let mut first_done = false;
    for ev in first.iter() {
        match ev {
            TokenEvent::Token { index, token } => {
                assert_eq!(index, first_tokens.len(), "in-order emission");
                first_tokens.push(token);
            }
            TokenEvent::Done(resp) => {
                assert_eq!(resp.tokens, first_tokens, "Done echoes the streamed tokens");
                first_done = true;
            }
            TokenEvent::Error(e) => panic!("stream 0 failed: {e}"),
        }
    }
    assert!(first_done, "stream 0 must terminate with Done");
    assert_eq!(
        first_tokens,
        oracle(&prompts[0], budgets[0]),
        "stream 0 diverged from sequential greedy"
    );

    for (i, s) in rest.into_iter().enumerate() {
        let done = s.wait().unwrap();
        let want = oracle(&prompts[i + 1], budgets[i + 1]);
        assert_eq!(
            done.tokens,
            want,
            "mid-step-admitted stream {} diverged from sequential greedy",
            i + 1
        );
    }
    assert_eq!(server.kv_blocks_in_use(), 0, "all arena blocks returned");
}

fn gen_inputs(problems: &[McqProblem], cfg: &PicoLlamaConfig) -> (Vec<Vec<usize>>, Vec<usize>) {
    let prompts: Vec<Vec<usize>> = problems.iter().take(8).map(|p| p.prompt.clone()).collect();
    // Varied budgets: some hit max_tokens, some run into max_seq.
    let budgets: Vec<usize> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| match i % 3 {
            0 => 3 + i,
            1 => cfg.max_seq - p.len(), // exactly to the context edge
            _ => cfg.max_seq,           // clamped by max_seq mid-flight
        })
        .collect();
    (prompts, budgets)
}

#[test]
fn continuous_batching_matches_sequential_greedy_packed() {
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let (prompts, budgets) = gen_inputs(&problems, &pm.config);
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .workers(4)
            .kv_block_positions(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_continuous_matches_sequential(&server, &prompts, &budgets, |p, n| {
        packed_oracle(&pm, p, n)
    });
}

#[test]
fn continuous_batching_matches_sequential_greedy_reference() {
    let (qm, problems) = setup();
    let ck = qm.effective_checkpoint();
    let (prompts, budgets) = gen_inputs(&problems, &ck.config);
    let server = Server::start(
        Backend::Reference(Box::new(ck.clone())),
        ServerConfig::builder()
            .workers(4)
            .kv_block_positions(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert_continuous_matches_sequential(&server, &prompts, &budgets, |p, n| {
        reference_oracle(&ck, p, n)
    });
}

#[test]
fn session_backlog_preserves_results_when_sessions_are_capped() {
    // max_sessions=1 forces every other request through the FIFO
    // backlog; outputs must still match the sequential oracle.
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .max_sessions(1)
            .kv_block_positions(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    let streams: Vec<_> = problems
        .iter()
        .take(4)
        .map(|p| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.prompt.clone(),
                    max_tokens: 5,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();
    for (p, s) in problems.iter().zip(streams) {
        let done = s.wait().unwrap();
        assert_eq!(done.tokens, packed_oracle(&pm, &p.prompt, 5));
        assert_eq!(done.finish, FinishReason::MaxTokens);
    }
    assert_eq!(server.kv_blocks_in_use(), 0);
}

#[test]
fn cancellation_returns_kv_blocks_to_the_arena() {
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm)),
        ServerConfig::builder()
            .kv_block_positions(2)
            .build()
            .unwrap(),
    )
    .unwrap();
    let stream = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 64, // long enough that we cancel mid-flight
            deadline: None,
        })
        .unwrap();
    // The session is live once the first token arrives — and holding
    // blocks for its reserved worst case.
    assert!(matches!(stream.recv(), Some(TokenEvent::Token { .. })));
    assert!(server.kv_blocks_in_use() > 0, "live session rents blocks");
    // Dropping the stream is the cancellation signal; the serve loop
    // notices at the next decode step and frees the session.
    drop(stream);
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.kv_blocks_in_use() > 0 {
        assert!(
            Instant::now() < deadline,
            "cancelled session never returned its {} blocks",
            server.kv_blocks_in_use()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[test]
fn expired_deadline_sheds_with_a_typed_error() {
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(Backend::Packed(Box::new(pm)), ServerConfig::default()).unwrap();
    // A deadline that has effectively already passed must come back as
    // a typed DeadlineExceeded — promptly, not as a hang.
    let err = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 8,
            deadline: Some(Duration::from_nanos(1)),
        })
        .unwrap()
        .wait()
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<ServeError>(),
        Some(&ServeError::DeadlineExceeded),
        "got: {err:#}"
    );
    // A generous deadline still completes normally.
    let done = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 4,
            deadline: Some(Duration::from_secs(60)),
        })
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(done.tokens.len(), 4);
    assert_eq!(server.kv_blocks_in_use(), 0);
}

#[test]
fn overload_sheds_synchronously_with_a_typed_error() {
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm)),
        ServerConfig::builder().queue_cap(1).build().unwrap(),
    )
    .unwrap();
    // First request occupies the only queue slot until it completes.
    let stream = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 64,
            deadline: None,
        })
        .unwrap();
    // The second submit must shed *synchronously* — the bounded queue
    // rejects it before it ever reaches the serve loop.
    let err = server
        .submit_generate(GenerateRequest {
            prompt: problems[1].prompt.clone(),
            max_tokens: 1,
            deadline: None,
        })
        .map(|_| ())
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<ServeError>(),
        Some(&ServeError::Overloaded),
        "got: {err:#}"
    );
    // Once the first request drains, capacity frees up again.
    stream.wait().unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    let done = loop {
        match server.submit_generate(GenerateRequest {
            prompt: problems[1].prompt.clone(),
            max_tokens: 2,
            deadline: None,
        }) {
            Ok(s) => break s.wait().unwrap(),
            Err(_) => {
                assert!(Instant::now() < deadline, "queue slot never freed");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    };
    assert_eq!(done.tokens.len(), 2);
}

#[test]
fn impossible_kv_footprint_sheds_with_a_typed_error() {
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    // One 4-position block total: any request needing more can never
    // be admitted and must shed as KvExhausted, not wait forever.
    let server = Server::start(
        Backend::Packed(Box::new(pm)),
        ServerConfig::builder()
            .kv_block_positions(4)
            .kv_blocks(1)
            .build()
            .unwrap(),
    )
    .unwrap();
    let err = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 16,
            deadline: None,
        })
        .unwrap()
        .wait()
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<ServeError>(),
        Some(&ServeError::KvExhausted),
        "got: {err:#}"
    );
    // A request that fits the single block still serves fine.
    let small = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt[..2].to_vec(),
            max_tokens: 2,
            deadline: None,
        })
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(small.tokens.len(), 2);
    assert_eq!(server.kv_blocks_in_use(), 0);
}

#[test]
fn invalid_generation_requests_are_typed() {
    let (qm, _) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let vocab = pm.config.vocab;
    let server = Server::start(Backend::Packed(Box::new(pm)), ServerConfig::default()).unwrap();
    // Empty prompt and out-of-vocab tokens are validation errors.
    for bad in [Vec::new(), vec![vocab + 5]] {
        let err = server
            .submit_generate(GenerateRequest {
                prompt: bad,
                max_tokens: 4,
                deadline: None,
            })
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Invalid(_))),
            "got: {err:#}"
        );
    }
}
