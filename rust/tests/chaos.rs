//! Deterministic chaos suite for fault-contained serving (DESIGN.md
//! §12). Every test arms the process-global failpoint registry with a
//! seeded [`FaultPlan`], drives mixed traffic through a real server,
//! and asserts the containment invariants:
//!
//! 1. **No deadlock** — every stream and score receiver resolves and
//!    the drain call returns (the tests terminate).
//! 2. **Survivors are bit-identical** — requests that were not hit by
//!    an injected fault produce exactly the fault-free oracle's output.
//! 3. **Exact error accounting** — the reason-labeled shed counters
//!    sum to exactly the typed errors clients observed, and the panic
//!    counter matches the contained-panic errors among them.
//! 4. **Occupancy is provably 0** — after the final drain the arena
//!    rents no blocks (`DrainReport::kv_blocks_in_use == 0`).
//!
//! Chaos runs are reproducible: the default seed matrix is fixed, and
//! `SPLITQUANT_CHAOS_SEED=<n>` pins a single seed (the CI chaos step
//! runs four of them). The failpoint registry, the obs enabled flag,
//! and the panic hook are process-global, so every test here holds one
//! shared (poison-tolerant) lock.

use std::sync::Mutex;
use std::time::Duration;

use splitquant::coordinator::server::{
    Backend, GenerateRequest, ServeError, Server, ServerConfig, TokenEvent,
};
use splitquant::data::{generate_problems, FactWorld, McqProblem};
use splitquant::eval::ProblemResult;
use splitquant::model::decode::DecodeState;
use splitquant::model::forward::{generate_greedy, Workspace};
use splitquant::model::packed::PackedModel;
use splitquant::model::quantized::{quantize_model, Method, QuantizedModel};
use splitquant::model::{Checkpoint, PicoLlamaConfig};
use splitquant::obs;
use splitquant::quant::Bits;
use splitquant::split::SplitConfig;
use splitquant::util::failpoint::{self, sites, FaultKind, FaultPlan, SiteFault};

// ---------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------

/// Serialize tests (global failpoint registry + obs flag + panic hook)
/// and start from a disarmed registry with recording on.
fn chaos_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    let g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoint::clear();
    obs::set_enabled(true);
    g
}

/// Silence the panic hook while injected panics are expected; restores
/// the default hook on drop (so real assertion failures stay visible).
struct QuietPanics;

impl QuietPanics {
    fn new() -> QuietPanics {
        std::panic::set_hook(Box::new(|_| {}));
        QuietPanics
    }
}

impl Drop for QuietPanics {
    fn drop(&mut self) {
        let _ = std::panic::take_hook();
    }
}

/// The seeds a chaos test sweeps: `SPLITQUANT_CHAOS_SEED` pins one
/// (the CI matrix), otherwise a fixed default set of four.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("SPLITQUANT_CHAOS_SEED")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
    {
        Some(seed) => vec![seed],
        None => vec![11, 23, 37, 53],
    }
}

fn setup() -> (QuantizedModel, Vec<McqProblem>) {
    let world = FactWorld::generate(16, 4, 8, 1);
    let mut cfg = PicoLlamaConfig::test();
    cfg.vocab = world.vocab_size();
    let ck = Checkpoint::random_init(&cfg, 7);
    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
    let problems = generate_problems(&world, 16, 5);
    (qm, problems)
}

fn packed_oracle(pm: &PackedModel, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let mut ws = Workspace::new(&pm.config, pm.config.max_seq);
    let mut scratch = pm.prewarmed_scratch();
    let mut state = DecodeState::new(&pm.config);
    pm.generate_greedy(prompt, n_new, &mut ws, &mut scratch, &mut state)
        .unwrap()
}

fn reference_oracle(ck: &Checkpoint, prompt: &[usize], n_new: usize) -> Vec<usize> {
    let mut ws = Workspace::new(&ck.config, ck.config.max_seq);
    generate_greedy(ck, prompt, n_new, &mut ws).unwrap()
}

fn fault(site: &str, kind: FaultKind, probability: f64, count: u64) -> SiteFault {
    SiteFault {
        site: site.to_string(),
        kind,
        probability,
        count,
    }
}

// ---------------------------------------------------------------------
// Error-accounting snapshots
// ---------------------------------------------------------------------

const SHED_REASONS: [&str; 7] = [
    "overloaded",
    "deadline",
    "kv_exhausted",
    "unsupported",
    "invalid",
    "internal",
    "shutting_down",
];

/// Sum of every reason-labeled shed counter — the server's total count
/// of typed errors handed to clients.
fn shed_total() -> u64 {
    SHED_REASONS
        .iter()
        .map(|r| obs::counter_with(obs::names::SERVE_SHED_TOTAL, &[("reason", r)]).value())
        .sum()
}

fn panics_total() -> u64 {
    obs::counter(obs::names::SERVER_PANICS_TOTAL).value()
}

fn watchdog_total() -> u64 {
    obs::counter(obs::names::WATCHDOG_CANCELLATIONS_TOTAL).value()
}

/// Bit-exact comparison of scoring results (logprobs compared by bits,
/// so a NaN regression cannot masquerade as equality).
fn assert_scores_identical(got: &ProblemResult, want: &ProblemResult, ctx: &str) {
    assert_eq!(got.chosen, want.chosen, "{ctx}: chosen diverged");
    assert_eq!(got.correct, want.correct, "{ctx}: correct diverged");
    let got_bits: Vec<u64> = got.logprobs.iter().map(|v| v.to_bits()).collect();
    let want_bits: Vec<u64> = want.logprobs.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "{ctx}: logprobs diverged");
}

// ---------------------------------------------------------------------
// The seeded chaos matrix
// ---------------------------------------------------------------------

/// The standing chaos plan: a hard panic site in the workers, a
/// poison/miss fault inside the prefix-cache lock, soft faults on the
/// serve-loop thread, and a bounded arena-reserve fault (bounded so the
/// admission retry path cannot livelock).
fn chaos_plan(seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        faults: vec![
            fault(sites::WORKER_FORWARD, FaultKind::Panic, 0.2, 0),
            fault(sites::PREFIX_CACHE_LOCK, FaultKind::Error, 0.3, 0),
            fault(sites::SERVER_ADMIT, FaultKind::Error, 0.15, 2),
            fault(sites::STREAM_EMIT, FaultKind::Error, 0.1, 2),
            fault(sites::ARENA_RESERVE, FaultKind::Error, 0.2, 2),
        ],
    }
}

/// Drive mixed score + generate traffic through `server` under
/// `chaos_plan(seed)` and assert every containment invariant. The
/// oracles are computed fault-free before arming.
fn run_chaos_matrix(
    server: &Server,
    problems: &[McqProblem],
    seed: u64,
    gen_oracle: impl Fn(&[usize], usize) -> Vec<usize>,
) {
    let n_scores = 8;
    let n_gens = 6;
    let max_tokens = 6;

    // Fault-free scoring oracle through the *same* server (identical
    // batching path), before any fault is armed.
    let score_oracle: Vec<ProblemResult> = problems
        .iter()
        .take(n_scores)
        .map(|p| server.score(p.clone()).unwrap().result)
        .collect();
    let gen_oracles: Vec<Vec<usize>> = problems
        .iter()
        .take(n_gens)
        .map(|p| gen_oracle(&p.prompt, max_tokens))
        .collect();

    let shed0 = shed_total();
    let panics0 = panics_total();

    failpoint::configure(chaos_plan(seed));
    let quiet = QuietPanics::new();
    let score_rx: Vec<_> = problems
        .iter()
        .take(n_scores)
        .map(|p| server.submit(p.clone()))
        .collect();
    let streams: Vec<_> = problems
        .iter()
        .take(n_gens)
        .map(|p| {
            server.submit_generate(GenerateRequest {
                prompt: p.prompt.clone(),
                max_tokens,
                deadline: None,
            })
        })
        .collect();

    let mut client_errors = 0u64;
    let mut panic_errors = 0u64;
    for (i, rx) in score_rx.into_iter().enumerate() {
        match rx.recv().expect("score channel resolves — no deadlock") {
            Ok(resp) => {
                assert_scores_identical(
                    &resp.result,
                    &score_oracle[i],
                    &format!("seed {seed}, surviving score {i}"),
                );
            }
            Err(e) => {
                assert!(
                    e.downcast_ref::<ServeError>().is_some(),
                    "seed {seed}: score error must be typed, got: {e:#}"
                );
                if format!("{e:#}").contains("worker panicked") {
                    panic_errors += 1;
                }
                client_errors += 1;
            }
        }
    }
    for (i, stream) in streams.into_iter().enumerate() {
        match stream {
            Err(e) => {
                assert!(
                    e.downcast_ref::<ServeError>().is_some(),
                    "seed {seed}: sync shed must be typed, got: {e:#}"
                );
                client_errors += 1;
            }
            Ok(s) => match s.wait() {
                Ok(done) => {
                    assert_eq!(
                        done.tokens, gen_oracles[i],
                        "seed {seed}: surviving stream {i} diverged from the fault-free oracle"
                    );
                }
                Err(e) => {
                    assert!(
                        e.downcast_ref::<ServeError>().is_some(),
                        "seed {seed}: stream error must be typed, got: {e:#}"
                    );
                    if format!("{e:#}").contains("worker panicked") {
                        panic_errors += 1;
                    }
                    client_errors += 1;
                }
            },
        }
    }
    drop(quiet);
    failpoint::clear();

    // Exact accounting: every typed client error was shed-counted once,
    // and the panic counter matches the contained-panic errors exactly.
    assert_eq!(
        shed_total() - shed0,
        client_errors,
        "seed {seed}: shed counters must sum to exactly the client-visible errors"
    );
    assert_eq!(
        panics_total() - panics0,
        panic_errors,
        "seed {seed}: panic counter must match contained-panic client errors"
    );

    // The scheduler survived: fresh fault-free traffic still serves.
    let done = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 3,
            deadline: None,
        })
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(done.tokens, gen_oracle(&problems[0].prompt, 3));

    // Occupancy is provably 0 at the end of the world.
    let report = server.drain(Some(Duration::from_secs(30))).unwrap();
    assert_eq!(
        report.kv_blocks_in_use, 0,
        "seed {seed}: drain must prove arena occupancy 0"
    );
    assert_eq!(server.kv_blocks_in_use(), 0);
}

#[test]
fn chaos_matrix_packed() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    for seed in chaos_seeds() {
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let server = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig::builder()
                .workers(2)
                .kv_block_positions(4)
                .build()
                .unwrap(),
        )
        .unwrap();
        run_chaos_matrix(&server, &problems, seed, |p, n| packed_oracle(&pm, p, n));
    }
}

#[test]
fn chaos_matrix_reference() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    for seed in chaos_seeds() {
        let ck = qm.effective_checkpoint();
        let server = Server::start(
            Backend::Reference(Box::new(ck.clone())),
            ServerConfig::builder()
                .workers(2)
                .kv_block_positions(4)
                .build()
                .unwrap(),
        )
        .unwrap();
        run_chaos_matrix(&server, &problems, seed, |p, n| reference_oracle(&ck, p, n));
    }
}

// ---------------------------------------------------------------------
// Targeted containment tests
// ---------------------------------------------------------------------

/// A panic in one worker is confined to its session: exactly one typed
/// `Internal` error, neighbors bit-identical, process alive.
#[test]
fn single_worker_panic_hits_exactly_one_session() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .workers(2)
            .kv_block_positions(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    let panics0 = panics_total();
    failpoint::configure(FaultPlan {
        seed: 1,
        faults: vec![fault(sites::WORKER_FORWARD, FaultKind::Panic, 1.0, 1)],
    });
    let quiet = QuietPanics::new();
    let streams: Vec<_> = problems
        .iter()
        .take(3)
        .map(|p| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.prompt.clone(),
                    max_tokens: 5,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();
    let results: Vec<_> = streams.into_iter().map(|s| s.wait()).collect();
    drop(quiet);
    failpoint::clear();

    let mut errors = 0;
    for (p, r) in problems.iter().zip(&results) {
        match r {
            Err(e) => {
                errors += 1;
                match e.downcast_ref::<ServeError>() {
                    Some(ServeError::Internal(msg)) => {
                        assert!(msg.contains("worker panicked"), "got: {msg}")
                    }
                    other => panic!("expected Internal, got {other:?}"),
                }
            }
            Ok(done) => assert_eq!(
                done.tokens,
                packed_oracle(&pm, &p.prompt, 5),
                "neighbor of the panicked session diverged"
            ),
        }
    }
    assert_eq!(errors, 1, "the single injected panic must hit exactly one session");
    assert_eq!(panics_total() - panics0, 1);
    assert_eq!(server.kv_blocks_in_use(), 0, "the panicked session released its blocks");
}

/// A panic *inside the prefix-cache lock scope* poisons the shared
/// mutex; later scorers must recover the guard and keep producing
/// bit-identical results (the cache degrades to misses, not errors).
#[test]
fn poisoned_prefix_cache_recovers_bit_identically() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm)),
        ServerConfig::builder().workers(2).build().unwrap(),
    )
    .unwrap();
    let oracle: Vec<ProblemResult> = problems
        .iter()
        .take(6)
        .map(|p| server.score(p.clone()).unwrap().result)
        .collect();

    failpoint::configure(FaultPlan {
        seed: 2,
        faults: vec![fault(sites::PREFIX_CACHE_LOCK, FaultKind::Panic, 1.0, 1)],
    });
    let quiet = QuietPanics::new();
    let results: Vec<_> = problems
        .iter()
        .take(6)
        .map(|p| server.score(p.clone()))
        .collect();
    drop(quiet);
    failpoint::clear();

    let mut errors = 0;
    for (i, r) in results.into_iter().enumerate() {
        match r {
            Err(e) => {
                errors += 1;
                assert!(
                    matches!(e.downcast_ref::<ServeError>(), Some(ServeError::Internal(_))),
                    "got: {e:#}"
                );
            }
            Ok(resp) => assert_scores_identical(&resp.result, &oracle[i], &format!("score {i}")),
        }
    }
    assert_eq!(errors, 1, "one panic, one failed scoring request");
    // The lock is poisoned but recovered: scoring still works and still
    // matches the oracle bit for bit.
    for (i, p) in problems.iter().take(6).enumerate() {
        let resp = server.score(p.clone()).unwrap();
        assert_scores_identical(&resp.result, &oracle[i], &format!("post-poison score {i}"));
    }
}

// ---------------------------------------------------------------------
// Drain lifecycle
// ---------------------------------------------------------------------

#[test]
fn drain_idle_server_reports_zero_and_closes_admissions() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(Backend::Packed(Box::new(pm)), ServerConfig::default()).unwrap();

    let report = server.drain(None).unwrap();
    assert_eq!(report.completed, 0);
    assert_eq!(report.cancelled, 0);
    assert_eq!(report.shed, 0);
    assert_eq!(report.kv_blocks_in_use, 0);

    // Admissions are closed for both request kinds, typed.
    let err = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 2,
            deadline: None,
        })
        .map(|_| ())
        .unwrap_err();
    assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::ShuttingDown));
    let err = server.score(problems[0].clone()).unwrap_err();
    assert_eq!(err.downcast_ref::<ServeError>(), Some(&ServeError::ShuttingDown));

    // Draining twice is idempotent.
    let again = server.drain(Some(Duration::from_millis(1))).unwrap();
    assert_eq!(again.kv_blocks_in_use, 0);
}

#[test]
fn drain_completes_one_live_session() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder().kv_block_positions(4).build().unwrap(),
    )
    .unwrap();
    // Slow every decode step so the session is provably still live
    // when the drain request lands (a Delay failpoint passes through
    // normally afterwards — output stays bit-identical).
    failpoint::configure(FaultPlan {
        seed: 5,
        faults: vec![fault(
            sites::WORKER_FORWARD,
            FaultKind::Delay(Duration::from_millis(5)),
            1.0,
            0,
        )],
    });
    let stream = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 8,
            deadline: None,
        })
        .unwrap();
    // The session is live before we drain.
    assert!(matches!(stream.recv(), Some(TokenEvent::Token { .. })));
    let report = server.drain(None).unwrap();
    failpoint::clear();
    assert_eq!(report.completed, 1, "the live session ran to completion");
    assert_eq!(report.cancelled, 0);
    assert_eq!(report.kv_blocks_in_use, 0);
    let done = stream.wait().unwrap();
    assert_eq!(done.tokens, packed_oracle(&pm, &problems[0].prompt, 8));
}

/// Many sessions: the live ones complete, the backlogged ones shed
/// with `ShuttingDown`, and occupancy lands on exactly 0.
#[test]
fn drain_completes_live_sessions_and_sheds_backlog() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .max_sessions(2)
            .kv_block_positions(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    // Slow the decode steps so the two live sessions cannot finish —
    // and free backlog slots — before the drain request is routed.
    failpoint::configure(FaultPlan {
        seed: 6,
        faults: vec![fault(
            sites::WORKER_FORWARD,
            FaultKind::Delay(Duration::from_millis(5)),
            1.0,
            0,
        )],
    });
    let streams: Vec<_> = problems
        .iter()
        .take(5)
        .map(|p| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.prompt.clone(),
                    max_tokens: 16,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();
    // Two sessions are live (max_sessions), three sit in the backlog.
    let report = server.drain(None).unwrap();
    failpoint::clear();
    assert_eq!(report.kv_blocks_in_use, 0);
    let mut ok = 0;
    let mut shed = 0;
    for (p, s) in problems.iter().zip(streams) {
        match s.wait() {
            Ok(done) => {
                assert_eq!(done.tokens, packed_oracle(&pm, &p.prompt, 16));
                ok += 1;
            }
            Err(e) => {
                assert_eq!(
                    e.downcast_ref::<ServeError>(),
                    Some(&ServeError::ShuttingDown),
                    "got: {e:#}"
                );
                shed += 1;
            }
        }
    }
    assert_eq!((ok, shed), (2, 3), "live sessions complete, backlog sheds");
    assert_eq!(report.completed, 2);
    assert_eq!(report.shed, 3);
    assert_eq!(server.kv_blocks_in_use(), 0);
}

/// Speculative sessions rent 2× blocks (target + draft K/V); drain
/// must return every one of them.
#[test]
fn drain_returns_speculative_double_blocks() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let draft = std::sync::Arc::new(pm.clone());
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .kv_block_positions(4)
            .draft(Some(draft))
            .draft_k(2)
            .build()
            .unwrap(),
    )
    .unwrap();
    let streams: Vec<_> = problems
        .iter()
        .take(2)
        .map(|p| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.prompt.clone(),
                    max_tokens: 8,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();
    let report = server.drain(None).unwrap();
    assert_eq!(report.kv_blocks_in_use, 0, "target AND draft blocks returned");
    for (p, s) in problems.iter().zip(streams) {
        match s.wait() {
            // Speculative decoding preserves bit-identity with plain
            // greedy, drain or no drain.
            Ok(done) => assert_eq!(done.tokens, packed_oracle(&pm, &p.prompt, 8)),
            Err(e) => assert_eq!(
                e.downcast_ref::<ServeError>(),
                Some(&ServeError::ShuttingDown),
                "got: {e:#}"
            ),
        }
    }
    assert_eq!(server.kv_blocks_in_use(), 0);
}

/// A drain deadline cancels stragglers with the typed `ShuttingDown`
/// and still proves occupancy 0.
#[test]
fn drain_deadline_cancels_stragglers() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm)),
        ServerConfig::builder().kv_block_positions(2).build().unwrap(),
    )
    .unwrap();
    let stream = server
        .submit_generate(GenerateRequest {
            prompt: problems[0].prompt.clone(),
            max_tokens: 64, // long enough to still be running at the deadline
            deadline: None,
        })
        .unwrap();
    assert!(matches!(stream.recv(), Some(TokenEvent::Token { .. })));
    let report = server.drain(Some(Duration::ZERO)).unwrap();
    assert_eq!(report.cancelled, 1, "the straggler was deadline-cancelled");
    assert_eq!(report.kv_blocks_in_use, 0);
    let err = stream.wait().unwrap_err();
    assert_eq!(
        err.downcast_ref::<ServeError>(),
        Some(&ServeError::ShuttingDown),
        "got: {err:#}"
    );
}

// ---------------------------------------------------------------------
// Watchdog
// ---------------------------------------------------------------------

/// An injected decode-step delay trips the watchdog on exactly one
/// session; its neighbors finish bit-identically, and the cancellation
/// is a typed `Internal` naming the watchdog.
#[test]
fn watchdog_cancels_slow_session_without_disturbing_neighbors() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let server = Server::start(
        Backend::Packed(Box::new(pm.clone())),
        ServerConfig::builder()
            .workers(2)
            .kv_block_positions(4)
            .watchdog_step_budget(Some(Duration::from_millis(50)))
            .build()
            .unwrap(),
    )
    .unwrap();
    let watchdog0 = watchdog_total();
    failpoint::configure(FaultPlan {
        seed: 3,
        faults: vec![fault(
            sites::WORKER_FORWARD,
            FaultKind::Delay(Duration::from_millis(200)),
            1.0,
            1,
        )],
    });
    let streams: Vec<_> = problems
        .iter()
        .take(3)
        .map(|p| {
            server
                .submit_generate(GenerateRequest {
                    prompt: p.prompt.clone(),
                    max_tokens: 6,
                    deadline: None,
                })
                .unwrap()
        })
        .collect();
    let results: Vec<_> = streams.into_iter().map(|s| s.wait()).collect();
    failpoint::clear();

    let mut cancelled = 0;
    for (p, r) in problems.iter().zip(&results) {
        match r {
            Err(e) => {
                cancelled += 1;
                match e.downcast_ref::<ServeError>() {
                    Some(ServeError::Internal(msg)) => {
                        assert!(msg.contains("watchdog"), "got: {msg}")
                    }
                    other => panic!("expected Internal watchdog error, got {other:?}"),
                }
            }
            Ok(done) => assert_eq!(
                done.tokens,
                packed_oracle(&pm, &p.prompt, 6),
                "neighbor of the watchdog-cancelled session diverged"
            ),
        }
    }
    assert_eq!(cancelled, 1, "exactly the delayed session is cancelled");
    assert_eq!(watchdog_total() - watchdog0, 1);
    assert_eq!(server.kv_blocks_in_use(), 0, "cancellation released the blocks");
}

// ---------------------------------------------------------------------
// Admission validation (satellite: typed vocab checks on both kinds)
// ---------------------------------------------------------------------

/// Out-of-vocab (and otherwise malformed) scoring requests come back
/// as typed `Invalid` on both CPU engines — they never reach a
/// worker's forward pass, where they would assert.
#[test]
fn invalid_score_requests_are_typed_on_both_engines() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let ck = qm.effective_checkpoint();
    let vocab = pm.config.vocab;
    for backend in [
        Backend::Packed(Box::new(pm)),
        Backend::Reference(Box::new(ck)),
    ] {
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let mut bad_token = problems[0].clone();
        bad_token.prompt[0] = vocab + 3;
        let mut bad_option = problems[1].clone();
        bad_option.options[0] = vec![vocab + 1];
        let mut empty_prompt = problems[2].clone();
        empty_prompt.prompt.clear();
        for bad in [bad_token, bad_option, empty_prompt] {
            let err = server.score(bad).unwrap_err();
            assert!(
                matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Invalid(_))),
                "got: {err:#}"
            );
        }
        // A well-formed problem on the same server still scores.
        assert!(server.score(problems[3].clone()).is_ok());
    }
}

/// The generation twin: out-of-vocab prompts shed as typed `Invalid`
/// at admission on both engines.
#[test]
fn invalid_generate_requests_are_typed_on_both_engines() {
    let _g = chaos_lock();
    let (qm, problems) = setup();
    let pm = PackedModel::from_qmodel(&qm).unwrap();
    let ck = qm.effective_checkpoint();
    let vocab = pm.config.vocab;
    for backend in [
        Backend::Packed(Box::new(pm)),
        Backend::Reference(Box::new(ck)),
    ] {
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        for bad in [Vec::new(), vec![vocab], vec![1, 2, vocab + 7]] {
            let err = server
                .submit_generate(GenerateRequest {
                    prompt: bad,
                    max_tokens: 4,
                    deadline: None,
                })
                .unwrap()
                .wait()
                .unwrap_err();
            assert!(
                matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Invalid(_))),
                "got: {err:#}"
            );
        }
        let done = server
            .submit_generate(GenerateRequest {
                prompt: problems[0].prompt.clone(),
                max_tokens: 2,
                deadline: None,
            })
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(done.tokens.len(), 2);
        assert_eq!(server.kv_blocks_in_use(), 0);
    }
}

// ---------------------------------------------------------------------
// Metrics endpoint containment
// ---------------------------------------------------------------------

/// An injected fault on a `/metrics` scrape answers 500 and the
/// endpoint keeps serving the next scrape.
#[test]
fn metrics_endpoint_survives_injected_scrape_fault() {
    use std::io::{Read as _, Write as _};
    let _g = chaos_lock();
    obs::counter("chaos_itest_probe_total").inc();
    let srv = splitquant::obs::http::serve("127.0.0.1:0").unwrap();
    let get = |path: &str| {
        let mut s = std::net::TcpStream::connect(srv.addr()).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };
    failpoint::configure(FaultPlan {
        seed: 4,
        faults: vec![fault(sites::METRICS_ACCEPT, FaultKind::Error, 1.0, 1)],
    });
    let faulted = get("/metrics");
    failpoint::clear();
    assert!(faulted.starts_with("HTTP/1.1 500"), "got: {faulted}");
    assert!(faulted.contains("injected error"));
    let healthy = get("/metrics");
    assert!(healthy.starts_with("HTTP/1.1 200 OK"), "got: {healthy}");
    assert!(healthy.contains("chaos_itest_probe_total"));
}
