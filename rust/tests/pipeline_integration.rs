//! Integration tests over the full pipeline: trained artifacts → split +
//! quantize → pack → reload → evaluate, plus CPU-vs-PJRT cross-checks.
//!
//! These run against the real `artifacts/` produced by `make artifacts`;
//! each test degrades to a skip (with a stderr note) when artifacts are
//! absent so `cargo test` stays green on a fresh clone.

use std::path::{Path, PathBuf};

use splitquant::coordinator::{Arm, Coordinator, PipelineSpec};
use splitquant::runtime::EngineKind;
use splitquant::data::load_problems;
use splitquant::io::checkpoint::load_checkpoint;
use splitquant::io::qmodel::{load_qmodel, save_qmodel};
use splitquant::model::quantized::{quantize_model, Method};
use splitquant::quant::Bits;
use splitquant::split::SplitConfig;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() && p.join("picollama_eval.sqtz").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn spec(dir: &Path) -> PipelineSpec {
    PipelineSpec::new(
        dir.join("picollama_eval.sqtz"),
        dir.join("eval_problems.json"),
    )
}

#[test]
fn trained_checkpoint_loads_and_is_memorized() {
    let Some(dir) = artifacts() else { return };
    let ck = load_checkpoint(dir.join("picollama_eval.sqtz")).unwrap();
    assert_eq!(ck.config.vocab, 211);
    assert!(ck.meta.contains_key("fact_accuracy"));
    let acc: f64 = ck.meta["fact_accuracy"].parse().unwrap();
    assert!(acc > 0.9, "training failed to memorize: {acc}");
    // Unperturbed model near-perfect on the eval set.
    let (problems, vocab) = load_problems(dir.join("eval_problems.json")).unwrap();
    assert_eq!(vocab, ck.config.vocab);
    assert_eq!(problems.len(), 1165, "paper-sized problem set");
    let coord = Coordinator::new();
    let sample = &problems[..100];
    let rep = splitquant::eval::evaluate(&ck, sample, &coord.pool).unwrap();
    assert!(rep.accuracy > 0.95, "FP accuracy {}", rep.accuracy_pct());
}

#[test]
fn full_arm_roundtrip_through_disk() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::new();
    let s = spec(&dir);
    let ck = coord.load_model(&s).unwrap();
    let (problems, _) = load_problems(dir.join("eval_problems.json")).unwrap();
    let sample = &problems[..64];

    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
        .unwrap();
    let tmp = std::env::temp_dir().join("sq_integration_arm.sqtz");
    save_qmodel(&tmp, &qm).unwrap();
    let back = load_qmodel(&tmp).unwrap();

    // Accuracy identical before/after the disk roundtrip — on both CPU
    // engines (the packed engine consumes the same packed planes the
    // container stores).
    for engine in [EngineKind::Reference, EngineKind::Packed] {
        let a = coord.evaluate_qm(&qm, sample, false, engine).unwrap();
        let b = coord.evaluate_qm(&back, sample, false, engine).unwrap();
        assert_eq!(a.n_correct, b.n_correct, "{}", engine.name());
    }
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn packed_engine_matches_reference_choices() {
    // The `--engine packed` acceptance check: same chosen answers as the
    // reference engine on the bundled eval set, on every quantized arm.
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::new();
    let s = spec(&dir);
    let ck = coord.load_model(&s).unwrap();
    let (problems, _) = load_problems(dir.join("eval_problems.json")).unwrap();
    let sample = &problems[..200];
    for (bits, method) in [
        (Bits::Int8, Method::Baseline),
        (Bits::Int4, Method::Baseline),
        (Bits::Int4, Method::SplitQuant(SplitConfig::default())),
    ] {
        let qm = quantize_model(&ck, bits, &method).unwrap();
        let pm = splitquant::model::packed::PackedModel::from_qmodel(&qm).unwrap();
        let eff = qm.effective_checkpoint();
        let mut ref_bufs = splitquant::eval::ScoreBuffers::new(&ck.config, ck.config.max_seq);
        let mut packed_bufs = splitquant::eval::ScoreBuffers::for_packed(&pm, ck.config.max_seq);
        for p in sample {
            let reference = splitquant::eval::score_problem(&eff, p, &mut ref_bufs).unwrap();
            let packed =
                splitquant::eval::score_problem_packed(&pm, p, &mut packed_bufs).unwrap();
            // Identical choices on every decided problem; only FP-noise
            // ties may flip between summation orders.
            if reference.chosen != packed.chosen {
                assert!(
                    reference.margin() < 1e-3,
                    "{}/{}: choice flipped at margin {}",
                    bits.name(),
                    qm.method_name,
                    reference.margin()
                );
            }
        }
        // Aggregate accuracies also agree through the coordinator path.
        let a = coord
            .evaluate_qm(&qm, sample, false, EngineKind::Reference)
            .unwrap();
        let b = coord
            .evaluate_qm(&qm, sample, false, EngineKind::Packed)
            .unwrap();
        assert!(
            (a.accuracy - b.accuracy).abs() <= 2.0 / sample.len() as f64,
            "{}/{}: reference {} vs packed {}",
            bits.name(),
            qm.method_name,
            a.accuracy_pct(),
            b.accuracy_pct()
        );
    }
}

#[test]
fn cpu_and_pjrt_scoring_agree_fp() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::with_engine(&dir, Some(&["score_fp"])).unwrap();
    let s = spec(&dir);
    let ck = coord.load_model(&s).unwrap();
    let (problems, _) = load_problems(dir.join("eval_problems.json")).unwrap();
    let sample = &problems[..96];
    let cpu = coord.evaluate_fp(&ck, sample, false).unwrap();
    let pjrt = coord.evaluate_fp(&ck, sample, true).unwrap();
    // Identical choices modulo FP noise at decision boundaries.
    assert!(
        (cpu.accuracy - pjrt.accuracy).abs() <= 2.0 / sample.len() as f64,
        "CPU {} vs PJRT {}",
        cpu.accuracy_pct(),
        pjrt.accuracy_pct()
    );
}

#[test]
fn cpu_and_pjrt_scoring_agree_quantized_arms() {
    let Some(dir) = artifacts() else { return };
    let coord =
        Coordinator::with_engine(&dir, Some(&["score_quant_k1", "score_quant_k3"])).unwrap();
    let s = spec(&dir);
    let ck = coord.load_model(&s).unwrap();
    let (problems, _) = load_problems(dir.join("eval_problems.json")).unwrap();
    let sample = &problems[..96];
    for method in [
        Method::Baseline,
        Method::SplitQuant(SplitConfig::default()),
    ] {
        let arm = Arm {
            bits: Bits::Int4,
            method,
        };
        let (qm, _) = coord.quantize_arm(&ck, &arm).unwrap();
        let cpu = coord
            .evaluate_qm(&qm, sample, false, EngineKind::Reference)
            .unwrap();
        let pjrt = coord
            .evaluate_qm(&qm, sample, true, EngineKind::Reference)
            .unwrap();
        assert!(
            (cpu.accuracy - pjrt.accuracy).abs() <= 2.0 / sample.len() as f64,
            "{}: CPU {} vs PJRT {}",
            arm.label(),
            cpu.accuracy_pct(),
            pjrt.accuracy_pct()
        );
    }
}

#[test]
fn table1_shape_holds_on_subset() {
    // The paper's qualitative claims on a 200-problem subset (fast).
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::new();
    let s = spec(&dir);
    let ck = coord.load_model(&s).unwrap();
    let (problems, _) = load_problems(dir.join("eval_problems.json")).unwrap();
    let sample = &problems[..200];

    let fp = coord.evaluate_fp(&ck, sample, false).unwrap();
    let mut acc = std::collections::BTreeMap::new();
    for arm in Coordinator::table1_arms(&SplitConfig::default()) {
        let res = coord.run_arm(&ck, &arm, sample, &s).unwrap();
        acc.insert(arm.label(), res.report.accuracy);
    }
    // INT8 ≈ FP both arms.
    assert!((acc["INT8/baseline"] - fp.accuracy).abs() < 0.05);
    assert!((acc["INT8/splitquantv2(k=3)"] - fp.accuracy).abs() < 0.05);
    // INT4 baseline degrades materially; SQv2 recovers most of it.
    assert!(
        fp.accuracy - acc["INT4/baseline"] > 0.10,
        "INT4 baseline should degrade: fp={} int4={}",
        fp.accuracy,
        acc["INT4/baseline"]
    );
    assert!(
        acc["INT4/splitquantv2(k=3)"] - acc["INT4/baseline"] > 0.10,
        "SQv2 should recover: {} vs {}",
        acc["INT4/splitquantv2(k=3)"],
        acc["INT4/baseline"]
    );
    assert!((fp.accuracy - acc["INT4/splitquantv2(k=3)"]) < 0.10);
    // INT2 collapses toward chance for both arms.
    assert!(acc["INT2/baseline"] < 0.45);
}

#[test]
fn server_batches_and_matches_offline_scoring() {
    use splitquant::coordinator::server::{Server, ServerConfig};
    use splitquant::runtime::scoring;
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::new();
    let s = spec(&dir);
    let ck = coord.load_model(&s).unwrap();
    let (problems, _) = load_problems(dir.join("eval_problems.json")).unwrap();
    let sample = &problems[..48];

    let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
        .unwrap();
    let offline = coord
        .evaluate_qm(&qm, sample, false, EngineKind::Reference)
        .unwrap();

    let weights = scoring::quant_args(&qm, 3).unwrap();
    let server = Server::start(
        splitquant::coordinator::server::Backend::Pjrt {
            artifacts_dir: dir.clone(),
            weight_args: weights,
        },
        ServerConfig::default(),
    )
    .unwrap();
    let rx: Vec<_> = sample.iter().map(|p| server.submit(p.clone())).collect();
    let mut correct = 0;
    let mut max_batch = 0;
    for r in rx {
        let resp = r.recv().unwrap().unwrap();
        correct += resp.result.is_correct() as usize;
        max_batch = max_batch.max(resp.batch_size);
    }
    assert!(max_batch > 1, "burst must batch");
    let served_acc = correct as f64 / sample.len() as f64;
    assert!(
        (served_acc - offline.accuracy).abs() <= 2.0 / sample.len() as f64,
        "served {} vs offline {}",
        served_acc,
        offline.accuracy_pct()
    );
}

#[test]
fn gptq_arm_integrates_with_eval() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::new();
    let s = spec(&dir);
    let ck = coord.load_model(&s).unwrap();
    let (problems, _) = load_problems(dir.join("eval_problems.json")).unwrap();
    let sample = &problems[..100];
    let world = splitquant::data::FactWorld::generate(120, 6, 80, 2026);
    let calib: Vec<Vec<usize>> = world.corpus(1, 99).into_iter().take(64).collect();
    let qm = splitquant::gptq::gptq_quantize_model(&ck, Bits::Int4, &calib, 0.01).unwrap();
    // Per-channel GPTQ grids run through the packed engine natively.
    let gptq = coord
        .evaluate_qm(&qm, sample, false, EngineKind::Packed)
        .unwrap();
    let base_arm = Arm {
        bits: Bits::Int4,
        method: Method::Baseline,
    };
    let base = coord.run_arm(&ck, &base_arm, sample, &s).unwrap();
    assert!(
        gptq.accuracy >= base.report.accuracy - 0.02,
        "gptq {} should not trail baseline {} materially",
        gptq.accuracy_pct(),
        base.report.accuracy_pct()
    );
}
