//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate links libxla/PJRT native libraries that are not part
//! of this container image. This stub mirrors exactly the API surface
//! `splitquant::runtime` uses, so the whole workspace builds and tests
//! offline; [`PjRtClient::cpu`] fails with a descriptive error, which the
//! runtime layer already treats as "PJRT unavailable" (integration tests
//! skip, the CPU reference path is used instead). Swap this path
//! dependency for the real `xla` crate to enable the PJRT runtime.

use std::fmt;

/// Error type for all stub operations. Matches the real crate's usage
/// pattern: callers format it with `{:?}` and convert with `?`.
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error("PJRT is unavailable: the workspace is built against the offline xla stub".into())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// XLA primitive type tags (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    S8,
    S32,
    F32,
}

/// A host-side literal value. The stub only carries shape metadata; no
/// literal ever reaches an executable because compilation always fails
/// first.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    shape: Vec<usize>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            shape: vec![values.len()],
        }
    }

    /// Reshape to explicit dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal {
            shape: dims.iter().map(|&d| d.max(0) as usize).collect(),
        })
    }

    /// Uninitialised literal of a primitive type and shape.
    pub fn create_from_shape(_ty: PrimitiveType, shape: &[usize]) -> Literal {
        Literal {
            shape: shape.to_vec(),
        }
    }

    /// Copy raw host bytes into the literal.
    pub fn copy_raw_from<T: NativeType>(&mut self, _values: &[T]) -> Result<()> {
        Ok(())
    }

    /// Unwrap a 1-tuple result.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    /// Read the literal back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// An XLA computation ready to compile.
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A device buffer holding an execution result.
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// The PJRT client. [`PjRtClient::cpu`] always fails in the stub.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().expect_err("stub must fail");
        assert!(format!("{err:?}").contains("unavailable"));
    }

    #[test]
    fn literal_shape_plumbing() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.shape(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        let mut s = Literal::create_from_shape(PrimitiveType::S8, &[3]);
        s.copy_raw_from(&[1i8, 2, 3]).unwrap();
        assert!(s.to_vec::<i8>().is_err());
    }
}
