//! Vendored, dependency-free subset of the `anyhow` crate.
//!
//! The splitquant build is fully offline (no crates.io), so this shim
//! provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Semantics match upstream
//! anyhow for that subset:
//!
//! * `Error` is NOT `std::error::Error` itself, so the blanket
//!   `From<E: std::error::Error>` conversion powers `?` without
//!   conflicting with `From<Error> for Error`.
//! * `{e}` displays the outermost message, `{e:#}` displays the whole
//!   cause chain joined by `: `, and `{e:?}` displays the chain in the
//!   multi-line "Caused by" form.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with an optional chain of context messages.
pub struct Error {
    /// Context layers, outermost first. The first entry is what plain
    /// `{}` formatting shows.
    context: Vec<String>,
    /// The underlying typed error, if the chain bottoms out in one.
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            context: vec![message.to_string()],
            source: None,
        }
    }

    /// Wrap an existing typed error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            context: Vec::new(),
            source: Some(Box::new(error)),
        }
    }

    /// Add an outer context layer (what [`Context::context`] does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.insert(0, context.to_string());
        self
    }

    /// Every message in the chain, outermost first.
    pub fn chain_messages(&self) -> Vec<String> {
        let mut out = self.context.clone();
        if let Some(s) = &self.source {
            out.push(s.to_string());
        }
        out
    }

    /// The innermost message (the root cause).
    pub fn root_cause_message(&self) -> String {
        self.chain_messages()
            .last()
            .cloned()
            .unwrap_or_else(|| "unknown error".to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_messages();
        if f.alternate() {
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain.first().map(String::as_str).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_messages();
        match chain.split_first() {
            Some((head, rest)) if !rest.is_empty() => {
                writeln!(f, "{head}")?;
                writeln!(f, "\nCaused by:")?;
                for (i, msg) in rest.iter().enumerate() {
                    writeln!(f, "    {i}: {msg}")?;
                }
                Ok(())
            }
            Some((head, _)) => write!(f, "{head}"),
            None => write!(f, "unknown error"),
        }
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error with an outer message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily-evaluated outer message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string. Tokens are forwarded to
/// `format!` unchanged, so positional args and inline `{name}` captures
/// both work exactly as upstream.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("file missing"));
    }

    #[test]
    fn context_layers_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(full.contains("file missing"), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_format() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 3;
        let b = anyhow!("got {x} and {}", 4);
        assert_eq!(b.to_string(), "got 3 and 4");
        fn bails() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "boom 1");
        fn ensures(n: i32) -> Result<()> {
            ensure!(n > 0, "n must be positive, got {n}");
            Ok(())
        }
        assert!(ensures(1).is_ok());
        assert!(ensures(-1).is_err());
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::new(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"), "{dbg}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
