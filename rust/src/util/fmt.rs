//! Human-readable formatting of sizes, counts and simple aligned tables —
//! used by every report the CLI and the bench harness print.

/// `1.23 GiB`-style byte formatting.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// `1.3B`, `12.5M`, `340K` parameter-count formatting.
pub fn human_count(n: u64) -> String {
    let v = n as f64;
    if v >= 1e9 {
        format!("{:.2}B", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}K", v / 1e3)
    } else {
        format!("{n}")
    }
}

/// Simple monospace table builder with per-column alignment.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                s += &format!(" {}{} |", c, " ".repeat(pad));
            }
            s
        };
        let mut out = line(&self.header);
        out.push('\n');
        out += "|";
        for w in &width {
            out += &format!("{}|", "-".repeat(w + 2));
        }
        out.push('\n');
        for r in &self.rows {
            out += &line(r);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1_300_000_000), "1.30B");
        assert_eq!(human_count(12_500_000), "12.50M");
        assert_eq!(human_count(5_300), "5.3K");
    }

    #[test]
    fn table_aligns() {
        let mut t = Table::new(&["name", "value"]);
        t.row_str(&["a", "1"]).row_str(&["longer", "22"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }
}
