//! A small declarative command-line parser (the offline build has no
//! `clap`). Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! positional arguments, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
    pub required: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
    pub positionals: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            args: Vec::new(),
            positionals: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
            required: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: true,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
            required: false,
        });
        self
    }

    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: true,
        });
        self
    }

    fn usage(&self, prog: &str) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {prog} {}", self.name, self.about, self.name);
        for p in &self.positionals {
            s += &format!(" <{}>", p.name);
        }
        s += " [OPTIONS]\n";
        if !self.positionals.is_empty() {
            s += "\nARGS:\n";
            for p in &self.positionals {
                s += &format!("  <{}>  {}\n", p.name, p.help);
            }
        }
        if !self.args.is_empty() {
            s += "\nOPTIONS:\n";
            for a in &self.args {
                let lhs = if a.is_flag {
                    format!("--{}", a.name)
                } else {
                    format!("--{} <v>", a.name)
                };
                let def = match a.default {
                    Some(d) => format!(" [default: {d}]"),
                    None if a.required => " [required]".to_string(),
                    None => String::new(),
                };
                s += &format!("  {lhs:24} {}{def}\n", a.help);
            }
        }
        s
    }
}

/// Parsed arguments for one subcommand invocation.
#[derive(Debug)]
pub struct Matches {
    pub command: &'static str,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Result<&str> {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing argument --{name}"))
    }

    pub fn get_opt(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)?
            .parse()
            .map_err(|_| anyhow!("--{name} must be an unsigned integer"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)?
            .parse()
            .map_err(|_| anyhow!("--{name} must be an unsigned integer"))
    }

    /// Millisecond option as a `Duration` (e.g. `--max-wait-ms 5`).
    pub fn get_ms(&self, name: &str) -> Result<std::time::Duration> {
        Ok(std::time::Duration::from_millis(self.get_u64(name)?))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)?
            .parse()
            .map_err(|_| anyhow!("--{name} must be a number"))
    }

    /// Comma-separated list of usizes, e.g. `--bits 2,4,8`.
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>> {
        self.get(name)?
            .split(',')
            .map(|p| {
                p.trim()
                    .parse()
                    .map_err(|_| anyhow!("--{name}: '{p}' is not an unsigned integer"))
            })
            .collect()
    }
}

/// Top-level application: subcommands + global help.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn overview(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for c in &self.commands {
            s += &format!("  {:18} {}\n", c.name, c.about);
        }
        s += &format!("\nRun '{} <COMMAND> --help' for command options.\n", self.name);
        s
    }

    /// Parse a raw argv (without the program name). Returns Err with the
    /// help text for `--help` / unknown commands so main can print & exit.
    pub fn parse(&self, argv: &[String]) -> Result<Matches> {
        let Some(cmd_name) = argv.first() else {
            bail!("{}", self.overview());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            bail!("{}", self.overview());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name.as_str())
            .ok_or_else(|| anyhow!("unknown command '{cmd_name}'\n\n{}", self.overview()))?;

        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for a in &cmd.args {
            if let Some(d) = a.default {
                values.insert(a.name.to_string(), d.to_string());
            }
        }

        let mut pos_iter = cmd.positionals.iter();
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                bail!("{}", cmd.usage(self.name));
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = cmd
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| {
                        anyhow!("unknown option '--{key}'\n\n{}", cmd.usage(self.name))
                    })?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    flags.insert(key.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow!("option --{key} needs a value"))?
                        }
                    };
                    values.insert(key.to_string(), val);
                }
            } else {
                let spec = pos_iter
                    .next()
                    .ok_or_else(|| {
                        anyhow!("unexpected positional '{tok}'\n\n{}", cmd.usage(self.name))
                    })?;
                values.insert(spec.name.to_string(), tok.clone());
            }
            i += 1;
        }

        for a in cmd.args.iter().chain(cmd.positionals.iter()) {
            if a.required && !a.is_flag && !values.contains_key(a.name) {
                bail!("missing required argument --{}\n\n{}", a.name, cmd.usage(self.name));
            }
        }

        Ok(Matches {
            command: cmd.name,
            values,
            flags,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("sq", "test app").command(
            Command::new("run", "run things")
                .req("model", "model path")
                .opt("bits", "4", "bit width")
                .flag("verbose", "chatty")
                .pos("input", "input file"),
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let m = app()
            .parse(&argv(&["run", "file.bin", "--model", "m.sqtz", "--verbose"]))
            .unwrap();
        assert_eq!(m.command, "run");
        assert_eq!(m.get("model").unwrap(), "m.sqtz");
        assert_eq!(m.get("input").unwrap(), "file.bin");
        assert_eq!(m.get_usize("bits").unwrap(), 4);
        assert!(m.flag("verbose"));
    }

    #[test]
    fn ms_option_parses_to_duration() {
        let m = app()
            .parse(&argv(&["run", "in", "--model", "m", "--bits", "250"]))
            .unwrap();
        assert_eq!(
            m.get_ms("bits").unwrap(),
            std::time::Duration::from_millis(250)
        );
        assert!(m.get_ms("model").is_err(), "non-numeric value errors");
    }

    #[test]
    fn equals_syntax() {
        let m = app()
            .parse(&argv(&["run", "in", "--model=m", "--bits=8"]))
            .unwrap();
        assert_eq!(m.get("bits").unwrap(), "8");
    }

    #[test]
    fn missing_required_errors() {
        assert!(app().parse(&argv(&["run", "in"])).is_err());
    }

    #[test]
    fn unknown_command_and_option_error() {
        assert!(app().parse(&argv(&["zap"])).is_err());
        assert!(app()
            .parse(&argv(&["run", "in", "--model", "m", "--nope", "1"]))
            .is_err());
    }

    #[test]
    fn help_bails_with_usage() {
        let err = app().parse(&argv(&["run", "--help"])).unwrap_err();
        assert!(err.to_string().contains("USAGE"));
    }

    #[test]
    fn usize_list() {
        let m = app()
            .parse(&argv(&["run", "in", "--model", "m", "--bits", "2,4,8"]))
            .unwrap();
        assert_eq!(m.get_usize_list("bits").unwrap(), vec![2, 4, 8]);
    }
}
