//! Deterministic, zero-dependency fault injection (failpoints).
//!
//! A failpoint is a named site in production code where a test (or a
//! demo run via `SPLITQUANT_FAULTS`) can inject a fault: a panic, a
//! typed error message, or a delay. Sites call [`trigger`] (or
//! [`trigger_soft`] where a panic must never originate — scheduler
//! threads and `Drop` paths) and act on the returned message.
//!
//! Design goals, in order:
//!
//! 1. **Near-free when disabled.** The fast path is a single relaxed
//!    atomic load of a global `ARMED` flag; no site lookup, no lock,
//!    no allocation. The serving hot loop pays one predictable branch.
//! 2. **Deterministic.** Every injection decision is a pure function
//!    of `(plan seed, site name, per-site hit index)` via a
//!    SplitMix64-style mixer, so a chaos run with a fixed seed fails
//!    (or passes) identically on every machine and every rerun —
//!    probability without nondeterminism.
//! 3. **Zero dependencies.** `std` only, like the rest of `util`.
//!
//! The registry is process-global (like `obs`): tests that arm real
//! sites must serialize on a shared mutex within their binary. Arming
//! fictitious site names is always safe — an armed registry returns
//! `None` for any site not named in the plan.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Canonical site names. Production code passes these to [`trigger`];
/// plans reference them by the same strings.
pub mod sites {
    /// KV arena block allocation (`KvArena::alloc`). `error` makes the
    /// allocation report exhaustion; reachable from admission reserve
    /// and prefix-cache snapshot restore.
    pub const ARENA_RESERVE: &str = "arena.reserve";
    /// KV arena block release (`KvArena::release`). Runs inside `Drop`
    /// during unwinds, so the site is soft: an injected panic is
    /// downgraded to an (ignored) error and `delay` is the only
    /// observable fault.
    pub const ARENA_RELEASE: &str = "arena.release";
    /// Prefix-cache lookup/insert, fired *inside* the cache lock scope
    /// so an injected panic poisons the shared mutex — the recovery
    /// path the server must survive.
    pub const PREFIX_CACHE_LOCK: &str = "prefix_cache.lock";
    /// Per-item worker forward pass: one scored problem or one decode
    /// step of one session. The bread-and-butter chaos site.
    pub const WORKER_FORWARD: &str = "worker.forward";
    /// Speculative decoding draft catch-up, before the draft model
    /// re-extends over accepted target tokens.
    pub const SPECDEC_CATCH_UP: &str = "specdec.catch_up";
    /// Admission control on the serve-loop thread (soft site).
    pub const SERVER_ADMIT: &str = "server.admit";
    /// Token event emission on the serve-loop thread (soft site).
    pub const STREAM_EMIT: &str = "stream.emit";
    /// Per-connection handling in the `/metrics` HTTP endpoint.
    pub const METRICS_ACCEPT: &str = "metrics.accept";
}

/// What an armed site does when the deterministic coin lands on fire.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// Panic with a message naming the site. Downgraded to an error
    /// return at soft sites or while the thread is already panicking
    /// (a panic inside `Drop` during unwind aborts the process).
    Panic,
    /// Return an error message for the site to convert into its typed
    /// error path.
    Error,
    /// Sleep for the given duration, then proceed normally. Used to
    /// exercise the watchdog and deadline paths.
    Delay(Duration),
}

/// One armed site within a [`FaultPlan`].
#[derive(Clone, Debug)]
pub struct SiteFault {
    /// Site name, matched exactly against [`trigger`] callers.
    pub site: String,
    /// The fault to inject when the coin fires.
    pub kind: FaultKind,
    /// Per-hit fire probability in `[0, 1]`. `1.0` fires every hit.
    pub probability: f64,
    /// Maximum number of fires; `0` means unlimited.
    pub count: u64,
}

/// A seeded set of armed sites. Installed with [`configure`]; the
/// seed makes every probabilistic decision reproducible.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Seed mixed into every per-hit fire decision.
    pub seed: u64,
    /// The armed sites. Sites absent from the plan never fire.
    pub faults: Vec<SiteFault>,
}

impl FaultPlan {
    /// Parse a plan from the `SPLITQUANT_FAULTS` syntax: `;`-separated
    /// `site=kind` clauses where `kind` is `panic`, `error`, or
    /// `delay:<millis>`, optionally suffixed with `@<probability>`
    /// (default 1.0) and `x<count>` (default unlimited). Example:
    ///
    /// ```text
    /// worker.forward=panic@0.5x3;arena.release=delay:10;server.admit=error@0.2
    /// ```
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (site, rhs) = clause
                .split_once('=')
                .ok_or_else(|| format!("fault clause `{clause}` is missing `=`"))?;
            let mut rest = rhs.trim();
            let mut count = 0u64;
            if let Some((head, n)) = rest.rsplit_once('x') {
                if let Ok(n) = n.parse::<u64>() {
                    count = n;
                    rest = head;
                }
            }
            let mut probability = 1.0f64;
            if let Some((head, p)) = rest.rsplit_once('@') {
                probability = p
                    .parse::<f64>()
                    .map_err(|_| format!("bad probability `{p}` in `{clause}`"))?;
                if !(0.0..=1.0).contains(&probability) {
                    return Err(format!("probability `{p}` outside [0, 1] in `{clause}`"));
                }
                rest = head;
            }
            let kind = match rest {
                "panic" => FaultKind::Panic,
                "error" => FaultKind::Error,
                delay if delay.starts_with("delay") => {
                    let ms = delay
                        .strip_prefix("delay")
                        .and_then(|s| s.strip_prefix(':'))
                        .ok_or_else(|| format!("delay in `{clause}` needs `:millis`"))?
                        .parse::<u64>()
                        .map_err(|_| format!("bad delay millis in `{clause}`"))?;
                    FaultKind::Delay(Duration::from_millis(ms))
                }
                other => return Err(format!("unknown fault kind `{other}` in `{clause}`")),
            };
            faults.push(SiteFault { site: site.trim().to_string(), kind, probability, count });
        }
        if faults.is_empty() {
            return Err("fault plan is empty".to_string());
        }
        Ok(FaultPlan { seed, faults })
    }

    /// Build a plan from the `SPLITQUANT_FAULTS` env var (and
    /// `SPLITQUANT_FAULTS_SEED`, default 0). `None` when the var is
    /// unset or empty; `Err` on a malformed spec.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        let spec = match std::env::var("SPLITQUANT_FAULTS") {
            Ok(s) if !s.trim().is_empty() => s,
            _ => return Ok(None),
        };
        let seed = std::env::var("SPLITQUANT_FAULTS_SEED")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(0);
        FaultPlan::parse(&spec, seed).map(Some)
    }
}

struct SiteState {
    fault: SiteFault,
    hits: u64,
    fired: u64,
}

/// Fast-path gate: a single relaxed load when no plan is installed.
static ARMED: AtomicBool = AtomicBool::new(false);

struct Registry {
    seed: u64,
    sites: HashMap<String, SiteState>,
}

fn registry() -> &'static Mutex<Registry> {
    static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Registry { seed: 0, sites: HashMap::new() }))
}

fn lock_registry() -> std::sync::MutexGuard<'static, Registry> {
    // A panic injected at one site must not wedge the registry for
    // every later trigger — recover from poison unconditionally.
    registry().lock().unwrap_or_else(|e| e.into_inner())
}

/// Install a fault plan and arm the failpoints. Replaces any previous
/// plan and resets all hit/fire counters.
pub fn configure(plan: FaultPlan) {
    let mut reg = lock_registry();
    reg.seed = plan.seed;
    reg.sites = plan
        .faults
        .into_iter()
        .map(|f| (f.site.clone(), SiteState { fault: f, hits: 0, fired: 0 }))
        .collect();
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm all failpoints and clear the plan. Counters from the last
/// plan are discarded; read them with [`fired`] first.
pub fn clear() {
    ARMED.store(false, Ordering::SeqCst);
    let mut reg = lock_registry();
    reg.sites.clear();
}

/// Whether a plan is currently armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// How many times `site` has fired under the current plan (0 if the
/// site is unarmed). Fire = the coin landed and a fault was injected.
pub fn fired(site: &str) -> u64 {
    lock_registry().sites.get(site).map_or(0, |s| s.fired)
}

/// How many times `site` has been evaluated under the current plan.
pub fn hits(site: &str) -> u64 {
    lock_registry().sites.get(site).map_or(0, |s| s.hits)
}

/// Evaluate the failpoint at `site`.
///
/// Disabled (the common case): one relaxed atomic load, returns
/// `None`. Armed: a [`FaultKind::Panic`] fault panics from this call
/// (unless the thread is already panicking, which would abort the
/// process — then it degrades to an error return), a
/// [`FaultKind::Delay`] sleeps and returns `None`, and a
/// [`FaultKind::Error`] returns `Some(message)` for the caller to
/// convert into its typed error path.
#[inline]
pub fn trigger(site: &str) -> Option<String> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    trigger_slow(site, true)
}

/// Like [`trigger`], but never panics from this call: an injected
/// [`FaultKind::Panic`] degrades to an error return. For sites on the
/// serve-loop thread (where a panic would kill the scheduler for every
/// session) and sites reachable from `Drop`.
#[inline]
pub fn trigger_soft(site: &str) -> Option<String> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    trigger_slow(site, false)
}

#[cold]
fn trigger_slow(site: &str, may_panic: bool) -> Option<String> {
    let decision = {
        let mut reg = lock_registry();
        let seed = reg.seed;
        let state = reg.sites.get_mut(site)?;
        let hit = state.hits;
        state.hits += 1;
        if state.fault.count != 0 && state.fired >= state.fault.count {
            return None;
        }
        if !coin(seed, site, hit, state.fault.probability) {
            return None;
        }
        state.fired += 1;
        state.fault.kind.clone()
        // Registry lock drops here: a panic or sleep below must not
        // hold it, or concurrent triggers would poison/serialize.
    };
    match decision {
        FaultKind::Panic => {
            if may_panic && !std::thread::panicking() {
                panic!("failpoint `{site}` injected panic");
            }
            Some(format!("failpoint `{site}` injected panic (downgraded to error)"))
        }
        FaultKind::Error => Some(format!("failpoint `{site}` injected error")),
        FaultKind::Delay(d) => {
            std::thread::sleep(d);
            None
        }
    }
}

/// Deterministic fire decision for hit number `hit` at `site`:
/// SplitMix64-mix the seed, an FNV-1a hash of the site name, and the
/// hit index into 53 uniform bits, compared against `probability`.
fn coin(seed: u64, site: &str, hit: u64, probability: f64) -> bool {
    if probability >= 1.0 {
        return true;
    }
    if probability <= 0.0 {
        return false;
    }
    let h = mix(seed ^ fnv1a(site) ^ mix(hit.wrapping_add(0x9e3779b97f4a7c15)));
    let unit = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    unit < probability
}

fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; every test that arms it must
    // hold this (poison-tolerant) guard. Fictitious site names keep
    // these tests from interfering with any other test in the binary.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_is_none() {
        let _g = guard();
        clear();
        assert!(!armed());
        assert_eq!(trigger("test.nosuch"), None);
        assert_eq!(trigger_soft("test.nosuch"), None);
    }

    #[test]
    fn unarmed_site_is_none_even_when_armed() {
        let _g = guard();
        configure(FaultPlan {
            seed: 1,
            faults: vec![SiteFault {
                site: "test.armed".into(),
                kind: FaultKind::Error,
                probability: 1.0,
                count: 0,
            }],
        });
        assert_eq!(trigger("test.other"), None);
        assert!(trigger("test.armed").is_some());
        clear();
    }

    #[test]
    fn error_fires_and_counts() {
        let _g = guard();
        configure(FaultPlan {
            seed: 7,
            faults: vec![SiteFault {
                site: "test.err".into(),
                kind: FaultKind::Error,
                probability: 1.0,
                count: 2,
            }],
        });
        assert!(trigger("test.err").is_some());
        assert!(trigger("test.err").is_some());
        // Count cap reached: further hits pass through.
        assert_eq!(trigger("test.err"), None);
        assert_eq!(fired("test.err"), 2);
        assert_eq!(hits("test.err"), 3);
        clear();
    }

    #[test]
    fn probability_is_deterministic_per_seed() {
        let _g = guard();
        let plan = |seed| FaultPlan {
            seed,
            faults: vec![SiteFault {
                site: "test.coin".into(),
                kind: FaultKind::Error,
                probability: 0.5,
                count: 0,
            }],
        };
        let sample = |seed| {
            configure(plan(seed));
            let fires: Vec<bool> = (0..64).map(|_| trigger("test.coin").is_some()).collect();
            clear();
            fires
        };
        let a = sample(42);
        let b = sample(42);
        let c = sample(43);
        assert_eq!(a, b, "same seed must reproduce the same fire pattern");
        assert_ne!(a, c, "different seeds should diverge");
        let fires = a.iter().filter(|f| **f).count();
        assert!(
            (8..=56).contains(&fires),
            "p=0.5 over 64 hits fired {fires} times — mixer looks degenerate"
        );
    }

    #[test]
    fn panic_kind_panics_hard_and_degrades_soft() {
        let _g = guard();
        configure(FaultPlan {
            seed: 1,
            faults: vec![SiteFault {
                site: "test.boom".into(),
                kind: FaultKind::Panic,
                probability: 1.0,
                count: 0,
            }],
        });
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let caught = std::panic::catch_unwind(|| trigger("test.boom"));
        std::panic::set_hook(prev);
        assert!(caught.is_err(), "hard trigger must panic");
        let soft = trigger_soft("test.boom");
        assert!(soft.is_some_and(|m| m.contains("downgraded")));
        clear();
    }

    #[test]
    fn delay_sleeps_then_passes() {
        let _g = guard();
        configure(FaultPlan {
            seed: 1,
            faults: vec![SiteFault {
                site: "test.slow".into(),
                kind: FaultKind::Delay(Duration::from_millis(20)),
                probability: 1.0,
                count: 1,
            }],
        });
        let t0 = std::time::Instant::now();
        assert_eq!(trigger("test.slow"), None);
        assert!(t0.elapsed() >= Duration::from_millis(20));
        clear();
    }

    #[test]
    fn parse_round_trips_the_env_syntax() {
        let plan = FaultPlan::parse(
            "worker.forward=panic@0.5x3; arena.release=delay:10;server.admit=error@0.2",
            9,
        )
        .expect("parse");
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.faults[0].site, "worker.forward");
        assert_eq!(plan.faults[0].kind, FaultKind::Panic);
        assert_eq!(plan.faults[0].probability, 0.5);
        assert_eq!(plan.faults[0].count, 3);
        assert_eq!(plan.faults[1].kind, FaultKind::Delay(Duration::from_millis(10)));
        assert_eq!(plan.faults[1].probability, 1.0);
        assert_eq!(plan.faults[1].count, 0);
        assert_eq!(plan.faults[2].kind, FaultKind::Error);
        assert_eq!(plan.faults[2].probability, 0.2);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("", 0).is_err());
        assert!(FaultPlan::parse("noequals", 0).is_err());
        assert!(FaultPlan::parse("s=explode", 0).is_err());
        assert!(FaultPlan::parse("s=panic@1.5", 0).is_err());
        assert!(FaultPlan::parse("s=delay", 0).is_err());
        assert!(FaultPlan::parse("s=delay:abc", 0).is_err());
    }
}
