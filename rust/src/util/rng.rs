//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds an `Xoshiro256**` generator — the same construction
//! jax/numpy users rely on for reproducible experiments. All randomized
//! tests, data generators and property sweeps in this crate derive from
//! these generators so every run is bit-reproducible given a seed.

/// SplitMix64: used to expand a single `u64` seed into a full
/// xoshiro state. Also a perfectly fine standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-worker/per-layer RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.uniform() as f32) * (hi - lo)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value not kept:
    /// simplicity over the ~2x throughput, this is never on a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with given mean / std, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with iid N(mean, std).
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Student-t-ish heavy-tailed sample: normal / sqrt(chi2/df). Used to
    /// synthesize LLM-like weight distributions with outliers.
    pub fn heavy_tailed(&mut self, df: f64) -> f64 {
        let z = self.normal();
        // chi-square(df) via sum of df squared normals is slow for large
        // df; use the Wilson–Hilferty approximation instead.
        let x = {
            let c = 2.0 / (9.0 * df);
            let n = self.normal();
            df * (1.0 - c + n * c.sqrt()).powi(3)
        };
        z / (x / df).max(1e-12).sqrt()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index vector.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn heavy_tailed_has_outliers() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20_000).map(|_| r.heavy_tailed(3.0)).collect();
        let max = xs.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
        // A t(3) sample of 20k essentially always exceeds 5 sigma-equivalents.
        assert!(max > 5.0, "max={max}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(17);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
