//! Leveled, timestamped stderr logging (no `log` crate offline).
//!
//! Level is process-global, settable from the CLI (`--log debug`) or the
//! `SQ_LOG` environment variable. Macros mirror the `log` crate's.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialize from `SQ_LOG` if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SQ_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

#[doc(hidden)]
pub fn emit(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = t.as_secs();
    let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
    eprintln!(
        "{:02}:{:02}:{:02}.{:03} {} [{}] {}",
        h,
        m,
        s,
        t.subsec_millis(),
        l.tag(),
        module,
        msg
    );
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
