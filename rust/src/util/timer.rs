//! Wall-clock timing helpers and a tiny hierarchical profiler used by the
//! coordinator's stage reporting and the bench harness.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Measure one closure.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

/// Accumulating named-section profiler. Thread-safe; sections are
/// aggregated by name (count + total time) for the pipeline report.
#[derive(Default)]
pub struct Profiler {
    sections: Mutex<BTreeMap<String, (u64, Duration)>>,
}

impl Profiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and accumulate under `name`.
    pub fn section<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let v = f();
        self.record(name, t0.elapsed());
        v
    }

    pub fn record(&self, name: &str, d: Duration) {
        let mut m = self.sections.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert((0, Duration::ZERO));
        e.0 += 1;
        e.1 += d;
    }

    pub fn snapshot(&self) -> Vec<(String, u64, Duration)> {
        self.sections
            .lock()
            .unwrap()
            .iter()
            .map(|(k, (n, d))| (k.clone(), *n, *d))
            .collect()
    }

    /// Human-readable table, longest section first.
    pub fn report(&self) -> String {
        let mut rows = self.snapshot();
        rows.sort_by(|a, b| b.2.cmp(&a.2));
        let total: Duration = rows.iter().map(|r| r.2).sum();
        let mut s = String::new();
        s += &format!("{:<32} {:>8} {:>12} {:>7}\n", "section", "calls", "total", "share");
        for (name, count, dur) in &rows {
            let share = if total.as_nanos() > 0 {
                100.0 * dur.as_secs_f64() / total.as_secs_f64()
            } else {
                0.0
            };
            s += &format!(
                "{:<32} {:>8} {:>12} {:>6.1}%\n",
                name,
                count,
                format_duration(*dur),
                share
            );
        }
        s
    }
}

/// `1m 58s`-style formatting (matches how the paper reports times).
pub fn format_duration(d: Duration) -> String {
    let secs = d.as_secs_f64();
    if secs >= 60.0 {
        format!("{}m {:02.0}s", (secs / 60.0) as u64, secs % 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.is_zero());
    }

    #[test]
    fn profiler_accumulates() {
        let p = Profiler::new();
        p.section("a", || std::thread::sleep(Duration::from_millis(1)));
        p.section("a", || {});
        p.section("b", || {});
        let snap = p.snapshot();
        let a = snap.iter().find(|r| r.0 == "a").unwrap();
        assert_eq!(a.1, 2);
        assert!(a.2 >= Duration::from_millis(1));
        assert!(p.report().contains("section"));
    }

    #[test]
    fn duration_formats() {
        assert_eq!(format_duration(Duration::from_secs(126)), "2m 06s");
        assert_eq!(format_duration(Duration::from_millis(2500)), "2.50s");
        assert!(format_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(format_duration(Duration::from_millis(5)).ends_with("ms"));
    }
}
