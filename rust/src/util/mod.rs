//! Foundation utilities built from scratch (the build is fully offline:
//! only the `xla` crate and `anyhow` are available), so this module
//! provides what `rand`, `serde_json`, `clap`, `rayon` and `log` would
//! normally supply.

pub mod cli;
pub mod failpoint;
pub mod fmt;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;
