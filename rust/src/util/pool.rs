//! A small work-stealing-free thread pool with scoped parallel-for.
//!
//! The offline build has no `rayon`/`tokio`; this pool provides the
//! primitives the coordinator and the layer-pipeline engine need:
//! data-parallel sweeps (`parallel_for` / `parallel_map`), the bounded
//! ordered scheduler behind `pipeline::Engine`
//! (`parallel_consume_ordered`), and fire-and-forget `spawn` jobs.
//! On a 1-core container the pool degrades gracefully to near-sequential
//! execution with identical results (all parallel reductions in this crate
//! are order-independent or explicitly re-ordered by index).

use std::collections::BTreeMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct Pool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    /// Pool with `n` worker threads (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("sq-worker-{i}"))
                    .spawn(move || loop {
                        // Poison-tolerant: only `recv` ever runs under
                        // this lock, so recovered state is always valid.
                        let msg = { rx.lock().unwrap_or_else(|e| e.into_inner()).recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { tx, handles, size: n }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn new_auto() -> Self {
        let n = thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run `f(i)` for every i in 0..n, blocking until all complete.
    /// Work is distributed by an atomic cursor so uneven items balance.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        // Scoped threads let us borrow f without 'static.
        let cursor = AtomicUsize::new(0);
        let workers = self.size.min(n);
        thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Map each index to a value, preserving index order in the output.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.parallel_map_init(n, || (), |_, i| f(i))
    }

    /// [`parallel_map`](Pool::parallel_map) with **per-worker state**:
    /// `init()` runs once on each participating worker and the resulting
    /// value is threaded through every `f(&mut state, i)` call that
    /// worker claims. This is how the scoring paths hold one
    /// workspace/decode-state/kernel-scratch per worker instead of
    /// allocating per work item (the rayon `map_init` pattern).
    pub fn parallel_map_init<S, T, I, F>(&self, n: usize, init: I, f: F) -> Vec<T>
    where
        T: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots = Mutex::new(&mut out);
            let cursor = AtomicUsize::new(0);
            let workers = self.size.min(n);
            thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| {
                        let mut state = init();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            let v = f(&mut state, i);
                            // Short critical section: single slot store.
                            let mut guard = slots.lock().unwrap_or_else(|e| e.into_inner());
                            guard[i] = Some(v);
                        }
                    });
                }
            });
        }
        out.into_iter().map(|v| v.expect("all slots filled")).collect()
    }

    /// Visit `data.chunks_mut(chunk)` in parallel: `f(i, chunk_i)` runs
    /// exactly once per chunk, chunks are handed out dynamically (a slow
    /// chunk does not stall the others), and the call blocks until all
    /// complete. This is how the row-sharded GEMV path of
    /// `crate::kernels` gives each worker a disjoint output-row range
    /// without copies or unsafe aliasing — the chunk iterator itself is
    /// the work queue. Panics in `f` propagate (scoped-thread semantics).
    pub fn parallel_chunks<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if data.is_empty() {
            return;
        }
        let chunk = chunk.max(1);
        let n_chunks = data.len().div_ceil(chunk);
        let workers = self.size.min(n_chunks);
        if workers <= 1 {
            for (i, c) in data.chunks_mut(chunk).enumerate() {
                f(i, c);
            }
            return;
        }
        let queue = Mutex::new(data.chunks_mut(chunk).enumerate());
        thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let next = queue.lock().unwrap_or_else(|e| e.into_inner()).next();
                    match next {
                        Some((i, c)) => f(i, c),
                        None => break,
                    }
                });
            }
        });
    }

    /// Like [`parallel_map`](Pool::parallel_map), but with a bounded
    /// reorder window (see
    /// [`parallel_consume_ordered`](Pool::parallel_consume_ordered)).
    pub fn parallel_map_bounded<T, F>(&self, n: usize, window: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out = Vec::with_capacity(n);
        self.parallel_consume_ordered(n, window, f, |_, v| out.push(v));
        out
    }

    /// Bounded-memory ordered producer/consumer sweep — the scheduling
    /// core of the layer-pipeline engine.
    ///
    /// `produce(i)` runs on up to `size()` workers; `consume(i, value)`
    /// runs on the calling thread, strictly in index order, regardless of
    /// which worker finishes first. Workers never run more than `window`
    /// items ahead of the merge cursor, so at most `window` produced
    /// results are buffered at any time — a slow early item applies
    /// backpressure instead of letting the queue balloon (the
    /// bounded-memory layer queue of `pipeline::Engine`).
    ///
    /// A panic in `produce` or `consume` stops the sweep (workers drain
    /// and exit) and is re-raised on the calling thread, mirroring
    /// `thread::scope` semantics.
    pub fn parallel_consume_ordered<T, P, C>(
        &self,
        n: usize,
        window: usize,
        produce: P,
        mut consume: C,
    ) where
        T: Send,
        P: Fn(usize) -> T + Sync,
        C: FnMut(usize, T),
    {
        if n == 0 {
            return;
        }
        let window = window.max(1);
        let workers = self.size.min(n);
        if workers <= 1 {
            // Strictly sequential: produce and merge alternate in index
            // order; panics propagate natively.
            for i in 0..n {
                let v = produce(i);
                consume(i, v);
            }
            return;
        }

        struct OrderedState<T> {
            /// Produced-but-unmerged results (and panic payloads).
            buf: BTreeMap<usize, thread::Result<T>>,
            /// Next index the consumer will merge.
            merged: usize,
            /// Set on any panic: workers stop claiming and drain.
            poisoned: bool,
        }
        struct Shared<T> {
            state: Mutex<OrderedState<T>>,
            /// Workers wait here for window space.
            space: Condvar,
            /// The consumer waits here for the next in-order item.
            items: Condvar,
        }

        let shared: Shared<T> = Shared {
            state: Mutex::new(OrderedState {
                buf: BTreeMap::new(),
                merged: 0,
                poisoned: false,
            }),
            space: Condvar::new(),
            items: Condvar::new(),
        };
        let cursor = AtomicUsize::new(0);
        let mut consumer_panic: Option<Box<dyn std::any::Any + Send>> = None;

        thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    {
                        let mut g = shared.state.lock().unwrap();
                        while !g.poisoned && i >= g.merged + window {
                            g = shared.space.wait(g).unwrap();
                        }
                        if g.poisoned {
                            break;
                        }
                    }
                    let r = panic::catch_unwind(AssertUnwindSafe(|| produce(i)));
                    let mut g = shared.state.lock().unwrap();
                    if r.is_err() {
                        g.poisoned = true;
                        shared.space.notify_all();
                    }
                    g.buf.insert(i, r);
                    shared.items.notify_all();
                });
            }

            // In-order merge on the calling thread.
            let merge = panic::catch_unwind(AssertUnwindSafe(|| {
                for i in 0..n {
                    let r = {
                        let mut g = shared.state.lock().unwrap();
                        loop {
                            if let Some(r) = g.buf.remove(&i) {
                                break r;
                            }
                            if g.poisoned {
                                // Index i was abandoned by a draining
                                // worker; the payload sits in `buf`.
                                return;
                            }
                            g = shared.items.wait(g).unwrap();
                        }
                    };
                    match r {
                        Ok(v) => {
                            consume(i, v);
                            let mut g = shared.state.lock().unwrap();
                            g.merged = i + 1;
                            shared.space.notify_all();
                        }
                        Err(payload) => {
                            let mut g = shared.state.lock().unwrap();
                            g.poisoned = true;
                            g.buf.insert(i, Err(payload));
                            shared.space.notify_all();
                            return;
                        }
                    }
                }
            }));
            if let Err(p) = merge {
                // `consume` panicked: poison so blocked workers exit,
                // then re-raise after the scope joins them.
                let mut g = shared.state.lock().unwrap();
                g.poisoned = true;
                shared.space.notify_all();
                drop(g);
                consumer_panic = Some(p);
            }
        });

        if let Some(p) = consumer_panic {
            panic::resume_unwind(p);
        }
        let state = shared.state.into_inner().unwrap();
        if state.poisoned {
            for (_, r) in state.buf {
                if let Err(p) = r {
                    panic::resume_unwind(p);
                }
            }
            unreachable!("ordered sweep poisoned without a panic payload");
        }
    }
}

/// Split `total` threads between batch-level and row-level parallelism:
/// returns `(batch_workers, row_workers)` with `batch_workers =
/// min(total, items)` and the leftover cores folded into per-item row
/// parallelism (`row_workers = total / batch_workers`). A full batch
/// gets `(total, 1)` — all cores sharding items; a single decode stream
/// gets `(1, total)` — all cores sharding GEMV output rows. This is the
/// thread-budget rule shared by `eval::evaluate_packed` and the serving
/// executor so batch sharding and intra-forward row sharding never
/// oversubscribe each other.
pub fn thread_budget(total: usize, items: usize) -> (usize, usize) {
    let total = total.max(1);
    let batch = total.min(items.max(1));
    (batch, (total / batch).max(1))
}

impl Drop for Pool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.parallel_map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_init_reuses_state_per_worker() {
        let pool = Pool::new(3);
        let inits = AtomicUsize::new(0);
        let out = pool.parallel_map_init(
            64,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                // Per-worker counter: proves the state is threaded
                // through successive items on the same worker.
                0usize
            },
            |calls, i| {
                *calls += 1;
                (i, *calls)
            },
        );
        assert_eq!(out.len(), 64);
        assert!(out.iter().enumerate().all(|(i, (idx, _))| *idx == i));
        let n_inits = inits.load(Ordering::SeqCst);
        assert!(n_inits >= 1 && n_inits <= 3, "one init per worker, got {n_inits}");
        // Total calls across workers equals the item count.
        let per_worker_max: usize = out.iter().map(|&(_, c)| c).max().unwrap();
        assert!(per_worker_max >= 64 / 3, "state did not accumulate");
    }

    #[test]
    fn map_init_zero_items_never_inits() {
        let pool = Pool::new(2);
        let out: Vec<usize> =
            pool.parallel_map_init(0, || panic!("init must not run"), |_: &mut (), i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn spawn_runs_jobs() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = Pool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
        let v: Vec<usize> = pool.parallel_map(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = Pool::new(1);
        let out = pool.parallel_map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_map_matches_sequential_for_any_worker_count() {
        for workers in [1usize, 2, 3, 8] {
            for window in [1usize, 2, 7, 64] {
                let pool = Pool::new(workers);
                let out = pool.parallel_map_bounded(37, window, |i| i * 3 + 1);
                assert_eq!(
                    out,
                    (0..37).map(|i| i * 3 + 1).collect::<Vec<_>>(),
                    "workers={workers} window={window}"
                );
            }
        }
    }

    #[test]
    fn bounded_map_edge_counts() {
        // n = 1, n < workers, n = 0.
        let pool = Pool::new(6);
        assert_eq!(pool.parallel_map_bounded(1, 4, |i| i + 10), vec![10]);
        assert_eq!(pool.parallel_map_bounded(3, 1, |i| i), vec![0, 1, 2]);
        let empty: Vec<usize> = pool.parallel_map_bounded(0, 4, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn ordered_consume_sees_ascending_indices() {
        let pool = Pool::new(4);
        let mut seen = Vec::new();
        pool.parallel_consume_ordered(
            50,
            3,
            |i| {
                // Stagger completion so out-of-order production happens.
                if i % 7 == 0 {
                    thread::sleep(std::time::Duration::from_millis(2));
                }
                i
            },
            |i, v| {
                assert_eq!(i, v);
                seen.push(i);
            },
        );
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn window_bounds_in_flight_work() {
        // A worker may only claim index i once i < merged + window; with
        // the consumer's merge counter mirrored into an atomic, every
        // produce call must observe i < merged + window.
        let window = 4;
        let merged = AtomicUsize::new(0);
        let pool = Pool::new(8);
        pool.parallel_consume_ordered(
            200,
            window,
            |i| {
                let m = merged.load(Ordering::SeqCst);
                assert!(i < m + window, "index {i} ran ahead of merge {m} + window {window}");
                i
            },
            |i, _| {
                merged.store(i + 1, Ordering::SeqCst);
            },
        );
        assert_eq!(merged.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn produce_panic_propagates() {
        for workers in [1usize, 4] {
            let pool = Pool::new(workers);
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                pool.parallel_map_bounded(20, 2, |i| {
                    if i == 11 {
                        panic!("job 11 exploded");
                    }
                    i
                })
            }));
            let payload = r.expect_err("panic must cross the sweep");
            let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
            assert!(msg.contains("exploded"), "workers={workers}: payload {msg:?}");
        }
    }

    #[test]
    fn consume_panic_propagates() {
        let pool = Pool::new(3);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_consume_ordered(
                16,
                2,
                |i| i,
                |i, _| {
                    if i == 5 {
                        panic!("merge exploded");
                    }
                },
            )
        }));
        assert!(r.is_err());
    }

    #[test]
    fn parallel_chunks_visits_every_chunk_once() {
        for workers in [1usize, 2, 4, 8] {
            for chunk in [1usize, 3, 16, 100] {
                let pool = Pool::new(workers);
                let mut data = vec![0u32; 37];
                pool.parallel_chunks(&mut data, chunk, |i, c| {
                    for (j, v) in c.iter_mut().enumerate() {
                        *v += (i * chunk + j) as u32 + 1;
                    }
                });
                let want: Vec<u32> = (1..=37).collect();
                assert_eq!(data, want, "workers={workers} chunk={chunk}");
            }
        }
    }

    #[test]
    fn parallel_chunks_edge_cases() {
        let pool = Pool::new(4);
        let mut empty: Vec<u8> = Vec::new();
        pool.parallel_chunks(&mut empty, 4, |_, _| panic!("must not run"));
        let mut one = vec![0u8];
        pool.parallel_chunks(&mut one, 0, |i, c| {
            assert_eq!(i, 0);
            c[0] = 7; // chunk size clamps to 1
        });
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn parallel_chunks_panic_propagates() {
        let pool = Pool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut data = vec![0u8; 64];
            pool.parallel_chunks(&mut data, 4, |i, _| {
                if i == 7 {
                    panic!("chunk 7 exploded");
                }
            });
        }));
        assert!(r.is_err(), "chunk panic must escape");
    }

    #[test]
    fn thread_budget_splits_batch_then_rows() {
        assert_eq!(thread_budget(8, 1), (1, 8));
        assert_eq!(thread_budget(8, 8), (8, 1));
        assert_eq!(thread_budget(8, 3), (3, 2));
        assert_eq!(thread_budget(4, 100), (4, 1));
        assert_eq!(thread_budget(1, 5), (1, 1));
        assert_eq!(thread_budget(6, 0), (1, 6), "zero items still budgets one batch slot");
        assert_eq!(thread_budget(0, 3), (1, 1), "degenerate totals clamp to 1");
    }

    #[test]
    fn parallel_for_panic_propagates_out_of_scope() {
        // `thread::scope` re-raises worker panics when the scope joins;
        // the pipeline engine and callers rely on that contract.
        let pool = Pool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(10, |i| {
                if i == 3 {
                    panic!("scoped worker panic");
                }
            })
        }));
        assert!(r.is_err(), "worker panic must escape parallel_for");
        // The pool remains usable afterwards.
        let out = pool.parallel_map(4, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
