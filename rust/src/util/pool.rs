//! A small work-stealing-free thread pool with scoped parallel-for.
//!
//! The offline build has no `rayon`/`tokio`; this pool provides the two
//! primitives the coordinator needs: `parallel_for_chunks` (data-parallel
//! sweeps over layers / eval problems) and fire-and-forget `spawn` jobs.
//! On a 1-core container the pool degrades gracefully to near-sequential
//! execution with identical results (all parallel reductions in this crate
//! are order-independent or explicitly re-ordered by index).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct Pool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    /// Pool with `n` worker threads (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("sq-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { tx, handles, size: n }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn new_auto() -> Self {
        let n = thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run `f(i)` for every i in 0..n, blocking until all complete.
    /// Work is distributed by an atomic cursor so uneven items balance.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        // Scoped threads let us borrow f without 'static.
        let cursor = AtomicUsize::new(0);
        let workers = self.size.min(n);
        thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Map each index to a value, preserving index order in the output.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots = Mutex::new(&mut out);
            let cursor = AtomicUsize::new(0);
            let workers = self.size.min(n.max(1));
            thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let v = f(i);
                        // Short critical section: single slot store.
                        let mut guard = slots.lock().unwrap();
                        guard[i] = Some(v);
                    });
                }
            });
        }
        out.into_iter().map(|v| v.expect("all slots filled")).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.parallel_map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_runs_jobs() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = Pool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
        let v: Vec<usize> = pool.parallel_map(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = Pool::new(1);
        let out = pool.parallel_map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }
}
