//! Minimal JSON value model, parser and writer.
//!
//! Used for the artifact manifest (written by `python/compile/aot.py`),
//! experiment reports, and config files. Supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null); numbers
//! are held as f64, which is lossless for every integer this crate
//! exchanges (< 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- Accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers → Vec<usize> (shape fields in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // -- Builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- Writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON at byte {}", self.i))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.b[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate at byte {}", self.i);
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        e => bail!("bad escape '\\{}' at byte {}", e as char, self.i),
                    }
                }
                c if c < 0x20 => bail!("control char in string at byte {}", self.i),
                c => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8 at byte {start}");
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| anyhow!("invalid UTF-8 at byte {start}"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => bail!("bad hex digit at byte {}", self.i),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("invalid number '{text}' at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        for t in ["{", "[1,", "tru", "\"\\q\"", "{\"a\":}", "1 2"] {
            assert!(Json::parse(t).is_err(), "should reject {t}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // And raw multi-byte passthrough.
        let v = Json::parse("\"é😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.5).to_string(), "5.5");
    }

    #[test]
    fn usize_vec_accessor() {
        let v = Json::parse("[2,3,4]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![2, 3, 4]);
        assert!(Json::parse("[2,3.5]").unwrap().as_usize_vec().is_none());
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("model")),
            ("shape", Json::usizes(&[4, 8])),
            ("ok", Json::Bool(true)),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escaped_keys_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a\"b\n".to_string(), Json::Null);
        let v = Json::Obj(m);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
