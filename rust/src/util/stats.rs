//! Descriptive statistics over f64 samples — used by the bench harness
//! (mean/median/p95/stddev of timings) and by the eval reports.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p25: percentile_sorted(&sorted, 25.0),
            median: percentile_sorted(&sorted, 50.0),
            p75: percentile_sorted(&sorted, 75.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean of a slice (f32 input, f64 accumulation).
pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).abs())
        .fold(0.0, f64::max)
}

/// Ordinary least squares fit y = a + b·x. Returns (a, b, r²).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let syy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mse_and_maxdiff() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.5, 1.0];
        assert!((mse(&a, &b) - (0.25 + 4.0) / 3.0).abs() < 1e-9);
        assert!((max_abs_diff(&a, &b) - 2.0).abs() < 1e-9);
        assert_eq!(mse(&a, &a), 0.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }
}
