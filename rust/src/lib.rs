//! # splitquant — SplitQuantV2 reproduction
//!
//! A production-grade implementation of *SplitQuantV2: Enhancing Low-Bit
//! Quantization of LLMs Without GPUs* (Song & Lin, 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: CPU-only model
//!   preprocessing (k-means weight clustering → functionally-equivalent
//!   layer splitting → linear quantization), plus the full toolchain
//!   around it: model IR, checkpoint I/O, evaluation harness, baselines
//!   (plain linear quant, OCS, GPTQ-lite) and the PJRT runtime that
//!   executes AOT-lowered model graphs.
//! * **L2 (python/compile/model.py)** — the picollama transformer in JAX,
//!   lowered once to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the quantized
//!   matmul hot-spot, verified against pure-jnp oracles. On the CPU the
//!   same role is played by the [`kernels`] packed-integer engine:
//!   fused unpack-dequant GEMV/GEMM straight on bit-packed planes, the
//!   execution layer behind `eval`/`serve --engine packed`.
//!
//! Preprocessing is scheduled by the [`pipeline`] engine: each layer's
//! cluster → split+quantize → pack job is a work unit fanned out across
//! the worker pool (`--threads` on the CLI), merged deterministically so
//! the output is bit-identical to the sequential path.
//!
//! Runtime telemetry — the global metrics registry, span tracing, and
//! the `/metrics` endpoint behind `serve --metrics-addr` — lives in
//! [`obs`] (DESIGN.md §10), zero-dependency and near-free when
//! disabled.
//!
//! See README.md for the stack overview and how to run the tier-1
//! verify, DESIGN.md (repo root) for the design notes and experiment
//! index, and EXPERIMENTS.md for results.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod gptq;
pub mod io;
pub mod kernels;
pub mod kmeans;
pub mod model;
pub mod obs;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod split;
pub mod tensor;
pub mod util;
