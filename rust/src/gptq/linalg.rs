//! Dense symmetric-positive-definite linear algebra for GPTQ-lite:
//! Cholesky factorization, triangular inversion, and the upper-Cholesky
//! factor of H⁻¹ that GPTQ's update rule consumes.

/// Add GPTQ damping: H + λ·mean(diag(H))·I. Returns a copy.
pub fn damped(h: &[f64], n: usize, lambda: f64) -> Vec<f64> {
    assert_eq!(h.len(), n * n);
    let mean_diag = (0..n).map(|i| h[i * n + i]).sum::<f64>() / n as f64;
    let eps = (lambda * mean_diag).max(1e-10);
    let mut out = h.to_vec();
    for i in 0..n {
        out[i * n + i] += eps;
    }
    out
}

/// In-place lower Cholesky: A = L·Lᵀ; lower triangle of `a` becomes L.
/// Panics on non-PD input (damping prevents this in practice).
pub fn cholesky_lower(a: &mut [f64], n: usize) {
    assert_eq!(a.len(), n * n);
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        assert!(d > 0.0, "matrix not positive definite at pivot {j} (d={d})");
        let ljj = d.sqrt();
        a[j * n + j] = ljj;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / ljj;
        }
        // Zero the upper part for cleanliness.
        for k in (j + 1)..n {
            a[j * n + k] = 0.0;
        }
    }
}

/// Invert a lower-triangular matrix in place (forward substitution
/// column-by-column).
pub fn invert_lower(l: &mut [f64], n: usize) {
    for j in 0..n {
        l[j * n + j] = 1.0 / l[j * n + j];
        for i in (j + 1)..n {
            let mut s = 0.0;
            for k in j..i {
                s += l[i * n + k] * l[k * n + j];
            }
            l[i * n + j] = -s / l[i * n + i];
        }
    }
}

/// The upper-triangular factor `U` with `H⁻¹ = Uᵀ·U` — what GPTQ's
/// update rule consumes (torch's `cholesky(inv(H), upper=True)`).
///
/// Steps: H = L·Lᵀ → M = L⁻¹ → H⁻¹ = Mᵀ·M (dense symmetric) →
/// lower-Cholesky H⁻¹ = L₂·L₂ᵀ → U = L₂ᵀ.
/// Consumes `h` (damped Hessian), returns U row-major [n, n].
pub fn cholesky_inverse_upper(h: &mut [f64], n: usize) -> Vec<f64> {
    cholesky_lower(h, n);
    invert_lower(h, n);
    // Dense H⁻¹ = Mᵀ·M with M = L⁻¹ (lower): hinv[i][j] = Σ_k M[k][i]·M[k][j]
    // where k ≥ max(i, j).
    let mut hinv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in i..n {
            let mut s = 0.0;
            for k in j..n {
                s += h[k * n + i] * h[k * n + j];
            }
            hinv[i * n + j] = s;
            hinv[j * n + i] = s;
        }
    }
    cholesky_lower(&mut hinv, n);
    // U = L₂ᵀ.
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = hinv[i * n + j];
        }
    }
    u
}

/// Dense symmetric matrix–matrix check helper (tests): C = A·B.
#[cfg(test)]
pub fn matmul_f64(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(seed: u64, n: usize) -> Vec<f64> {
        let mut r = Rng::new(seed);
        // A = B·Bᵀ + n·I.
        let b: Vec<f64> = (0..n * n).map(|_| r.normal()).collect();
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s;
            }
            a[i * n + i] += n as f64;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let n = 12;
        let a = random_spd(1, n);
        let mut l = a.clone();
        cholesky_lower(&mut l, n);
        // L·Lᵀ == A.
        let mut lt = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                lt[i * n + j] = l[j * n + i];
            }
        }
        let rec = matmul_f64(&l, &lt, n);
        for (x, y) in rec.iter().zip(&a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn invert_lower_gives_inverse() {
        let n = 10;
        let a = random_spd(2, n);
        let mut l = a.clone();
        cholesky_lower(&mut l, n);
        let l_orig = l.clone();
        invert_lower(&mut l, n);
        let prod = matmul_f64(&l_orig, &l, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod[i * n + j] - want).abs() < 1e-8,
                    "({i},{j}): {}",
                    prod[i * n + j]
                );
            }
        }
    }

    #[test]
    fn cholesky_inverse_upper_is_hinv_factor() {
        let n = 8;
        let a = random_spd(3, n);
        let mut h = a.clone();
        let u = cholesky_inverse_upper(&mut h, n);
        // Uᵀ·U must equal A⁻¹, i.e. A·(Uᵀ·U) == I.
        let mut ut = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                ut[i * n + j] = u[j * n + i];
            }
        }
        let hinv = matmul_f64(&ut, &u, n);
        let prod = matmul_f64(&a, &hinv, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod[i * n + j] - want).abs() < 1e-7,
                    "({i},{j}): {}",
                    prod[i * n + j]
                );
            }
        }
        // And U is upper triangular.
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn damping_preserves_symmetry_and_grows_diag() {
        let n = 6;
        let a = random_spd(4, n);
        let d = damped(&a, n, 0.01);
        for i in 0..n {
            assert!(d[i * n + i] > a[i * n + i]);
            for j in 0..n {
                assert_eq!(d[i * n + j], d[j * n + i]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not positive definite")]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        cholesky_lower(&mut a, 2);
    }
}
