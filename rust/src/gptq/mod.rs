//! GPTQ-lite: a faithful CPU implementation of GPTQ's Hessian-based
//! error-compensating rounding (Frantar et al. 2022) — the
//! "advanced algorithm" comparator of the paper's §2.2.
//!
//! Per linear layer `W[out, in]` with calibration inputs `X[n, in]`:
//!
//! 1. `H = 2·XᵀX + λ·mean(diag)·I`  (damped Hessian of the layerwise
//!    least-squares objective)
//! 2. `Hinv = chol(H)⁻¹` upper-triangular factorization of H⁻¹
//! 3. Columns are quantized in order; the rounding error of column j is
//!    propagated into the not-yet-quantized columns via
//!    `W[:, j+1:] -= err · Hinv[j, j+1:] / Hinv[j, j]`.
//!
//! Quantization grid is per-row (out-channel) affine — the granularity
//! GPTQ uses for INT4. This comparator exists to reproduce the paper's
//! §2.2 claims: it needs calibration data, is O(in³ + out·in²) per layer
//! (vs SplitQuantV2's near-linear pass), and is dramatically slower on
//! CPU — while being an accuracy upper-bound worth comparing against.

pub mod linalg;

use std::collections::BTreeMap;

use crate::model::forward::{forward_tapped, Workspace};
use crate::model::quantized::{QuantParam, QuantizedModel};
use crate::model::{param_inventory, Checkpoint, ParamKind};
use crate::quant::{self, Bits, Granularity, QuantParams, QuantizedTensor};
use crate::tensor::{Tensor, TensorI8};

use anyhow::{anyhow, Result};
use self::linalg::{cholesky_inverse_upper, damped};

/// Accumulated calibration statistics for one linear layer.
#[derive(Clone, Debug)]
pub struct LayerHessian {
    pub in_dim: usize,
    /// XᵀX accumulated in f64, row-major [in, in].
    pub xtx: Vec<f64>,
    pub n_samples: usize,
}

impl LayerHessian {
    fn new(in_dim: usize) -> Self {
        Self {
            in_dim,
            xtx: vec![0.0; in_dim * in_dim],
            n_samples: 0,
        }
    }

    fn accumulate(&mut self, x: &[f32], seq: usize) {
        let d = self.in_dim;
        debug_assert_eq!(x.len(), seq * d);
        for t in 0..seq {
            let row = &x[t * d..(t + 1) * d];
            for i in 0..d {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let out = &mut self.xtx[i * d..(i + 1) * d];
                for (j, &xj) in row.iter().enumerate() {
                    out[j] += xi * xj as f64;
                }
            }
        }
        self.n_samples += seq;
    }
}

/// Run calibration sequences through the FP model, accumulating per-layer
/// Hessians (the GPTQ preprocessing the paper's §2.2 says SplitQuantV2
/// does *not* need).
pub fn calibrate(
    ck: &Checkpoint,
    sequences: &[Vec<usize>],
) -> Result<BTreeMap<String, LayerHessian>> {
    let mut hessians: BTreeMap<String, LayerHessian> = BTreeMap::new();
    for info in param_inventory(&ck.config) {
        if info.kind == ParamKind::Linear {
            hessians.insert(info.name.clone(), LayerHessian::new(info.shape[1]));
        }
    }
    let max_seq = sequences.iter().map(|s| s.len()).max().unwrap_or(8);
    let mut ws = Workspace::new(&ck.config, max_seq);
    for seq in sequences {
        forward_tapped(ck, seq, &mut ws, &mut |name, x, s| {
            if let Some(h) = hessians.get_mut(name) {
                h.accumulate(x, s);
            }
        })?;
    }
    Ok(hessians)
}

/// GPTQ quantization of one matrix given its Hessian.
pub fn gptq_quantize_matrix(
    w: &Tensor,
    hessian: &LayerHessian,
    bits: Bits,
    damp: f64,
) -> QuantizedTensor {
    assert_eq!(w.ndim(), 2);
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(cols, hessian.in_dim);

    // Per-row affine grid fixed up front (GPTQ's asymmetric per-channel).
    let params: Vec<QuantParams> = (0..rows)
        .map(|r| {
            let row = w.row(r);
            let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            QuantParams::from_range(bits, lo, hi)
        })
        .collect();

    // H⁻¹ upper Cholesky factor.
    let mut h = damped(&hessian.xtx, cols, damp);
    let hinv_u = cholesky_inverse_upper(&mut h, cols);

    // Working copy of W; quantize column by column with error propagation.
    let mut work: Vec<f32> = w.data().to_vec();
    let mut q_levels = vec![0i8; rows * cols];
    for j in 0..cols {
        let djj = hinv_u[j * cols + j];
        for r in 0..rows {
            let wv = work[r * cols + j];
            let q = params[r].quantize(wv);
            q_levels[r * cols + j] = q;
            let dq = params[r].dequantize(q);
            let err = (wv - dq) / djj as f32;
            // Propagate into the remaining columns of this row.
            let hrow = &hinv_u[j * cols..(j + 1) * cols];
            let wrow = &mut work[r * cols..(r + 1) * cols];
            for jj in (j + 1)..cols {
                wrow[jj] -= err * hrow[jj] as f32;
            }
        }
    }

    QuantizedTensor {
        plane: TensorI8::new(&[rows, cols], q_levels),
        granularity: Granularity::PerChannel,
        params,
    }
}

/// Full-model GPTQ: calibrate, then quantize every linear layer with
/// error compensation; embedding per-row, norms FP (same policy as the
/// other arms so comparisons are apples-to-apples).
pub fn gptq_quantize_model(
    ck: &Checkpoint,
    bits: Bits,
    calib: &[Vec<usize>],
    damp: f64,
) -> Result<QuantizedModel> {
    let hessians = calibrate(ck, calib)?;
    let mut linears = BTreeMap::new();
    let mut fp_tensors = BTreeMap::new();
    let mut embedding = None;
    for info in param_inventory(&ck.config) {
        let t = ck.get(&info.name)?;
        match info.kind {
            ParamKind::Norm => {
                fp_tensors.insert(info.name.clone(), t.clone());
            }
            ParamKind::Embedding => {
                embedding = Some(quant::quantize_per_channel(t, bits));
            }
            ParamKind::Linear => {
                let h = hessians
                    .get(&info.name)
                    .ok_or_else(|| anyhow!("no hessian for {}", info.name))?;
                linears.insert(
                    info.name.clone(),
                    QuantParam::Plain(gptq_quantize_matrix(t, h, bits, damp)),
                );
            }
        }
    }
    Ok(QuantizedModel {
        config: ck.config.clone(),
        bits,
        method_name: "gptq-lite".into(),
        linears,
        embedding: embedding.ok_or_else(|| anyhow!("no embedding"))?,
        fp_tensors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PicoLlamaConfig;
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    /// Output-space error ‖XWᵀ − XŴᵀ‖² — what GPTQ actually minimizes.
    fn output_mse(w: &Tensor, wq: &Tensor, xs: &Tensor) -> f64 {
        let y = crate::tensor::matmul(xs, &w.transpose());
        let yq = crate::tensor::matmul(xs, &wq.transpose());
        mse(y.data(), yq.data())
    }

    fn random_inputs(seed: u64, n: usize, d: usize) -> Tensor {
        let mut r = Rng::new(seed);
        let mut data = vec![0.0f32; n * d];
        // Correlated inputs (GPTQ's advantage shows with correlation).
        for row in 0..n {
            let base = r.normal_f32(0.0, 1.0);
            for i in 0..d {
                data[row * d + i] = 0.6 * base + r.normal_f32(0.0, 0.8);
            }
        }
        Tensor::new(&[n, d], data)
    }

    fn hessian_of(xs: &Tensor) -> LayerHessian {
        let mut h = LayerHessian::new(xs.cols());
        h.accumulate(xs.data(), xs.rows());
        h
    }

    #[test]
    fn hessian_is_symmetric_psd_diag() {
        let xs = random_inputs(1, 64, 16);
        let h = hessian_of(&xs);
        let d = h.in_dim;
        for i in 0..d {
            assert!(h.xtx[i * d + i] >= 0.0);
            for j in 0..d {
                assert!((h.xtx[i * d + j] - h.xtx[j * d + i]).abs() < 1e-9);
            }
        }
        assert_eq!(h.n_samples, 64);
    }

    #[test]
    fn gptq_beats_plain_rounding_in_output_space() {
        let mut r = Rng::new(2);
        let (out_d, in_d) = (24, 32);
        let mut wd = vec![0.0f32; out_d * in_d];
        r.fill_normal(&mut wd, 0.0, 0.4);
        let w = Tensor::new(&[out_d, in_d], wd);
        let xs = random_inputs(3, 256, in_d);
        let h = hessian_of(&xs);

        let gptq = gptq_quantize_matrix(&w, &h, Bits::Int4, 0.01).dequantize();
        let plain = quant::quantize_per_channel(&w, Bits::Int4).dequantize();

        let e_gptq = output_mse(&w, &gptq, &xs);
        let e_plain = output_mse(&w, &plain, &xs);
        assert!(
            e_gptq < e_plain,
            "gptq output-mse {e_gptq} must beat plain {e_plain}"
        );
    }

    #[test]
    fn gptq_levels_in_range() {
        let mut r = Rng::new(4);
        let w = Tensor::new(&[8, 12], (0..96).map(|_| r.normal_f32(0.0, 1.0)).collect());
        let xs = random_inputs(5, 64, 12);
        let q = gptq_quantize_matrix(&w, &hessian_of(&xs), Bits::Int2, 0.01);
        for &v in q.plane.data() {
            assert!((Bits::Int2.qmin()..=Bits::Int2.qmax()).contains(&(v as i32)));
        }
    }

    #[test]
    fn calibrate_covers_all_linears() {
        let cfg = PicoLlamaConfig::test();
        let ck = Checkpoint::random_init(&cfg, 6);
        let seqs = vec![vec![1, 2, 3, 4], vec![5, 6, 7]];
        let h = calibrate(&ck, &seqs).unwrap();
        assert_eq!(h.len(), cfg.n_layers * 7);
        for (name, lh) in &h {
            assert!(lh.n_samples == 7, "{name}: {}", lh.n_samples);
            assert!(lh.xtx.iter().any(|&v| v != 0.0), "{name} all-zero");
        }
    }

    #[test]
    fn gptq_model_end_to_end_beats_baseline_logits() {
        let cfg = PicoLlamaConfig::test();
        let mut ck = Checkpoint::random_init(&cfg, 7);
        ck.amplify_outliers(0.002, 10.0, 8);
        let calib: Vec<Vec<usize>> = (0..8)
            .map(|i| vec![1 + i % 5, 6 + i % 7, 13 + i % 11, 2])
            .collect();
        let gptq = gptq_quantize_model(&ck, Bits::Int4, &calib, 0.01)
            .unwrap()
            .effective_checkpoint();
        let base = crate::model::quantized::quantize_model(
            &ck,
            Bits::Int4,
            &crate::model::quantized::Method::Baseline,
        )
        .unwrap()
        .effective_checkpoint();
        let mut ws = Workspace::new(&cfg, 8);
        let toks = [1usize, 7, 14, 2];
        let fp = crate::model::forward::forward(&ck, &toks, &mut ws).unwrap();
        let lg = crate::model::forward::forward(&gptq, &toks, &mut ws).unwrap();
        let lb = crate::model::forward::forward(&base, &toks, &mut ws).unwrap();
        let eg = mse(fp.data(), lg.data());
        let eb = mse(fp.data(), lb.data());
        assert!(eg < eb, "gptq logit mse {eg} vs baseline {eb}");
    }
}
