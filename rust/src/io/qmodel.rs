//! Quantized-model container: packed integer planes + quantization
//! manifest, on top of SQTZ. This is the deployable artifact a target
//! NPU toolchain would ingest (E4 measures its size on disk).
//!
//! Entry naming:
//! * `lin.<param>.p<i>` — packed plane i of a linear layer (u8, bit-packed)
//! * `lin.<param>.eff`  — OCS layers: folded effective weight (f32)
//! * `emb.plane`        — packed embedding plane (u8)
//! * `emb.scales` / `emb.zps` — per-row embedding params (f32)
//! * `fp.<param>`       — FP32 passthrough (norm gains)
//!
//! The quantization manifest (scales, zero-points, cluster boundaries,
//! strategy) lives in `meta["quant_manifest"]` as JSON.

use std::collections::BTreeMap;
use std::path::Path;

use crate::kmeans::Clustering1D;
use crate::model::quantized::{QuantParam, QuantizedModel};
use crate::model::PicoLlamaConfig;
use crate::quant::{pack, Bits, Granularity, QuantParams, QuantizedTensor};
use crate::split::{QuantizedSplitLayer, Strategy};
use crate::tensor::{Tensor, TensorI8};
use crate::util::json::Json;

use super::{read_file, write_file, Entry};
use anyhow::{anyhow, bail, Result};

fn params_json(p: &QuantParams) -> Json {
    Json::obj(vec![
        ("scale", Json::num(p.scale)),
        ("zero_point", Json::num(p.zero_point as f64)),
    ])
}

fn params_from_json(j: &Json, bits: Bits) -> Result<QuantParams> {
    Ok(QuantParams {
        bits,
        scale: j.req("scale")?.as_f64().ok_or_else(|| anyhow!("bad scale"))?,
        zero_point: j
            .req("zero_point")?
            .as_i64()
            .ok_or_else(|| anyhow!("bad zero_point"))? as i32,
    })
}

fn clustering_json(c: &Clustering1D) -> Json {
    Json::obj(vec![
        (
            "centroids",
            Json::Arr(c.centroids.iter().map(|&v| Json::num(v)).collect()),
        ),
        (
            "boundaries",
            Json::Arr(c.boundaries.iter().map(|&v| Json::num(v)).collect()),
        ),
        ("inertia", Json::num(c.inertia)),
        (
            "sizes",
            Json::Arr(c.sizes.iter().map(|&v| Json::num(v)).collect()),
        ),
    ])
}

fn clustering_from_json(j: &Json) -> Result<Clustering1D> {
    let nums = |k: &str| -> Result<Vec<f64>> {
        j.req(k)?
            .as_arr()
            .ok_or_else(|| anyhow!("bad '{k}'"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad number in '{k}'")))
            .collect()
    };
    Ok(Clustering1D {
        centroids: nums("centroids")?,
        boundaries: nums("boundaries")?,
        inertia: j.req("inertia")?.as_f64().unwrap_or(0.0),
        sizes: nums("sizes")?,
        member_ranges: None,
    })
}

/// Save a quantized model.
pub fn save_qmodel(path: impl AsRef<Path>, qm: &QuantizedModel) -> Result<()> {
    let bits = qm.bits;
    let mut entries = Vec::new();
    let mut lin_manifest = BTreeMap::new();

    for (name, qp) in &qm.linears {
        match qp {
            QuantParam::Plain(q) => {
                entries.push(Entry::u8(
                    format!("lin.{name}.p0"),
                    q.plane.shape().to_vec(),
                    pack::pack(q.plane.data(), bits),
                ));
                lin_manifest.insert(
                    name.clone(),
                    Json::obj(vec![
                        ("kind", Json::str("plain")),
                        ("planes", Json::Arr(vec![params_json(&q.params[0])])),
                    ]),
                );
            }
            QuantParam::Split(s) => {
                let mut planes = Vec::new();
                for (i, p) in s.planes.iter().enumerate() {
                    entries.push(Entry::u8(
                        format!("lin.{name}.p{i}"),
                        p.plane.shape().to_vec(),
                        pack::pack(p.plane.data(), bits),
                    ));
                    planes.push(params_json(&p.params[0]));
                }
                lin_manifest.insert(
                    name.clone(),
                    Json::obj(vec![
                        ("kind", Json::str("split")),
                        (
                            "strategy",
                            Json::str(match s.strategy {
                                Strategy::MaskedSum => "masked_sum",
                                Strategy::RowWise => "row_wise",
                            }),
                        ),
                        ("planes", Json::Arr(planes)),
                        ("clustering", clustering_json(&s.clustering)),
                    ]),
                );
            }
            QuantParam::OcsEffective {
                effective,
                packed_len,
            } => {
                entries.push(Entry::f32(format!("lin.{name}.eff"), effective));
                lin_manifest.insert(
                    name.clone(),
                    Json::obj(vec![
                        ("kind", Json::str("ocs")),
                        ("packed_len", Json::num(*packed_len as f64)),
                    ]),
                );
            }
        }
    }

    // Embedding: per-row params.
    let emb = &qm.embedding;
    entries.push(Entry::u8(
        "emb.plane".to_string(),
        emb.plane.shape().to_vec(),
        pack::pack(emb.plane.data(), bits),
    ));
    // Scales must round-trip losslessly (f64): raw little-endian bytes.
    let mut scale_bytes = Vec::with_capacity(emb.params.len() * 8);
    for p in &emb.params {
        scale_bytes.extend_from_slice(&p.scale.to_le_bytes());
    }
    entries.push(Entry::u8(
        "emb.scales64".to_string(),
        vec![emb.params.len()],
        scale_bytes,
    ));
    entries.push(Entry::f32(
        "emb.zps",
        &Tensor::from_vec(emb.params.iter().map(|p| p.zero_point as f32).collect()),
    ));

    for (name, t) in &qm.fp_tensors {
        entries.push(Entry::f32(format!("fp.{name}"), t));
    }

    let manifest = Json::obj(vec![
        ("bits", Json::num(bits.width() as f64)),
        ("method", Json::str(qm.method_name.clone())),
        ("linears", Json::Obj(lin_manifest)),
    ]);
    let meta = BTreeMap::from([
        ("quant_manifest".to_string(), manifest.to_string()),
        ("format".to_string(), "splitquant-qmodel".to_string()),
    ]);
    write_file(path, &entries, &meta, Some(&qm.config.to_json()))
}

/// Load a quantized model.
pub fn load_qmodel(path: impl AsRef<Path>) -> Result<QuantizedModel> {
    let c = read_file(path)?;
    let config = PicoLlamaConfig::from_json(
        c.config
            .as_ref()
            .ok_or_else(|| anyhow!("qmodel missing config"))?,
    )?;
    let manifest = Json::parse(
        c.meta
            .get("quant_manifest")
            .ok_or_else(|| anyhow!("missing quant_manifest"))?,
    )?;
    let bits = Bits::from_width(
        manifest
            .req("bits")?
            .as_usize()
            .ok_or_else(|| anyhow!("bad bits"))?,
    )?;
    let method_name = manifest
        .req("method")?
        .as_str()
        .ok_or_else(|| anyhow!("bad method"))?
        .to_string();

    let unpack_plane = |entry: &str| -> Result<TensorI8> {
        let (shape, raw) = c.u8(entry)?;
        let n: usize = shape.iter().product();
        Ok(TensorI8::new(shape, pack::unpack(raw, n, bits)?))
    };

    let mut linears = BTreeMap::new();
    for (name, spec) in manifest
        .req("linears")?
        .as_obj()
        .ok_or_else(|| anyhow!("bad linears"))?
    {
        let kind = spec.req("kind")?.as_str().unwrap_or("");
        let qp = match kind {
            "plain" => {
                let plane = unpack_plane(&format!("lin.{name}.p0"))?;
                let params = params_from_json(&spec.req("planes")?.as_arr().unwrap()[0], bits)?;
                QuantParam::Plain(QuantizedTensor {
                    plane,
                    granularity: Granularity::PerTensor,
                    params: vec![params],
                })
            }
            "split" => {
                let plane_specs = spec.req("planes")?.as_arr().unwrap().to_vec();
                let mut planes = Vec::new();
                for (i, pj) in plane_specs.iter().enumerate() {
                    planes.push(QuantizedTensor {
                        plane: unpack_plane(&format!("lin.{name}.p{i}"))?,
                        granularity: Granularity::PerTensor,
                        params: vec![params_from_json(pj, bits)?],
                    });
                }
                let strategy = match spec.req("strategy")?.as_str().unwrap_or("") {
                    "masked_sum" => Strategy::MaskedSum,
                    "row_wise" => Strategy::RowWise,
                    s => bail!("unknown strategy '{s}'"),
                };
                QuantParam::Split(QuantizedSplitLayer {
                    planes,
                    clustering: clustering_from_json(spec.req("clustering")?)?,
                    strategy,
                })
            }
            "ocs" => QuantParam::OcsEffective {
                effective: c.f32(&format!("lin.{name}.eff"))?,
                packed_len: spec
                    .req("packed_len")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad packed_len"))?,
            },
            k => bail!("unknown linear kind '{k}'"),
        };
        linears.insert(name.clone(), qp);
    }

    // Embedding.
    let plane = unpack_plane("emb.plane")?;
    let (sshape, sraw) = c.u8("emb.scales64")?;
    let n_rows = sshape.iter().product::<usize>();
    if sraw.len() != n_rows * 8 {
        bail!("emb.scales64 length mismatch");
    }
    let scales: Vec<f64> = sraw
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let zps = c.f32("emb.zps")?;
    let params: Vec<QuantParams> = scales
        .iter()
        .zip(zps.data())
        .map(|(&s, &z)| QuantParams {
            bits,
            scale: s,
            zero_point: z as i32,
        })
        .collect();
    let embedding = QuantizedTensor {
        plane,
        granularity: Granularity::PerChannel,
        params,
    };

    let mut fp_tensors = BTreeMap::new();
    for name in c.names() {
        if let Some(stripped) = name.strip_prefix("fp.") {
            fp_tensors.insert(stripped.to_string(), c.f32(name)?);
        }
    }

    Ok(QuantizedModel {
        config,
        bits,
        method_name,
        linears,
        embedding,
        fp_tensors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::quantized::{quantize_model, Method};
    use crate::model::Checkpoint;
    use crate::split::SplitConfig;

    fn roundtrip(method: &Method, bits: Bits) {
        let ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 11);
        let qm = quantize_model(&ck, bits, method).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "sqtz_qm_{}_{}",
            qm.method_name.replace(['(', ')', '=', '≤'], "_"),
            bits.width()
        ));
        let path = dir.join("q.sqtz");
        save_qmodel(&path, &qm).unwrap();
        let back = load_qmodel(&path).unwrap();
        assert_eq!(back.bits, qm.bits);
        assert_eq!(back.method_name, qm.method_name);
        // Effective checkpoints must be identical (quantization is the
        // only lossy step; serialization is exact).
        let a = qm.effective_checkpoint();
        let b = back.effective_checkpoint();
        for (name, t) in &a.tensors {
            assert_eq!(b.tensors.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_baseline_all_bits() {
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            roundtrip(&Method::Baseline, bits);
        }
    }

    #[test]
    fn roundtrip_split() {
        roundtrip(&Method::SplitQuant(SplitConfig::default()), Bits::Int4);
        roundtrip(&Method::SplitQuant(SplitConfig::with_k(2)), Bits::Int2);
    }

    #[test]
    fn roundtrip_ocs() {
        roundtrip(&Method::Ocs { expand_ratio: 0.05 }, Bits::Int4);
    }

    #[test]
    fn on_disk_size_tracks_packed_bytes() {
        let ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 12);
        let qm = quantize_model(&ck, Bits::Int4, &Method::Baseline).unwrap();
        let dir = std::env::temp_dir().join("sqtz_qm_size");
        let path = dir.join("q.sqtz");
        save_qmodel(&path, &qm).unwrap();
        let disk = std::fs::metadata(&path).unwrap().len();
        let logical = qm.packed_bytes();
        // Disk = logical + header + alignment; must be within 25%.
        assert!(disk >= logical, "disk {disk} < logical {logical}");
        assert!(
            (disk as f64) < logical as f64 * 1.25 + 4096.0,
            "disk {disk} ≫ logical {logical}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
