//! SQTZ — the cross-language tensor container shared between the Python
//! build path (`python/compile/sqtz.py`) and this crate.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//!   0      4  magic  b"SQTZ"
//!   4      4  u32    version (1)
//!   8      8  u64    header length H
//!   16     H  bytes  JSON header (UTF-8)
//!   16+H   …  bytes  tensor payload, each tensor at its header offset
//! ```
//!
//! Header schema:
//! ```json
//! { "meta":    { "<key>": "<value>", ... },
//!   "config":  { ...optional model config... },
//!   "tensors": { "<name>": { "dtype": "f32|i8|u8|i32",
//!                            "shape": [..],
//!                            "offset": 0, "nbytes": 0 }, ... } }
//! ```
//!
//! Offsets are relative to the start of the payload and 16-byte aligned
//! (safetensors-style) so planes can be mmapped/zero-copied by NPU
//! toolchains.

pub mod checkpoint;
pub mod qmodel;

use std::collections::BTreeMap;
use std::fs;
use std::io::Write as _;
use std::path::Path;

use crate::tensor::DType;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"SQTZ";
pub const VERSION: u32 = 1;
const ALIGN: usize = 16;

/// One tensor entry to be written.
pub struct Entry {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl Entry {
    pub fn f32(name: impl Into<String>, t: &crate::tensor::Tensor) -> Entry {
        Entry {
            name: name.into(),
            dtype: DType::F32,
            shape: t.shape().to_vec(),
            bytes: t.to_le_bytes(),
        }
    }

    pub fn i8(name: impl Into<String>, t: &crate::tensor::TensorI8) -> Entry {
        Entry {
            name: name.into(),
            dtype: DType::I8,
            shape: t.shape().to_vec(),
            bytes: t.data().iter().map(|&v| v as u8).collect(),
        }
    }

    pub fn u8(name: impl Into<String>, shape: Vec<usize>, bytes: Vec<u8>) -> Entry {
        Entry {
            name: name.into(),
            dtype: DType::U8,
            shape,
            bytes,
        }
    }
}

/// A parsed SQTZ file held in memory.
pub struct Container {
    pub meta: BTreeMap<String, String>,
    pub config: Option<Json>,
    tensors: BTreeMap<String, (DType, Vec<usize>, Vec<u8>)>,
}

impl Container {
    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn raw(&self, name: &str) -> Result<(&DType, &[usize], &[u8])> {
        let (d, s, b) = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow!("tensor '{name}' not in container"))?;
        Ok((d, s, b))
    }

    pub fn f32(&self, name: &str) -> Result<crate::tensor::Tensor> {
        let (d, s, b) = self.raw(name)?;
        if *d != DType::F32 {
            bail!("tensor '{name}' is {}, expected f32", d.name());
        }
        crate::tensor::Tensor::from_le_bytes(s, b)
    }

    pub fn i8(&self, name: &str) -> Result<crate::tensor::TensorI8> {
        let (d, s, b) = self.raw(name)?;
        if *d != DType::I8 {
            bail!("tensor '{name}' is {}, expected i8", d.name());
        }
        Ok(crate::tensor::TensorI8::new(
            s,
            b.iter().map(|&v| v as i8).collect(),
        ))
    }

    pub fn u8(&self, name: &str) -> Result<(&[usize], &[u8])> {
        let (d, s, b) = self.raw(name)?;
        if *d != DType::U8 {
            bail!("tensor '{name}' is {}, expected u8", d.name());
        }
        Ok((s, b))
    }
}

/// Serialize entries + metadata into SQTZ bytes.
pub fn to_bytes(
    entries: &[Entry],
    meta: &BTreeMap<String, String>,
    config: Option<&Json>,
) -> Vec<u8> {
    // Lay out payload with alignment.
    let mut tensor_json = BTreeMap::new();
    let mut payload: Vec<u8> = Vec::new();
    for e in entries {
        let numel: usize = e.shape.iter().product();
        let expect = match e.dtype {
            DType::U8 => e.bytes.len(), // packed planes: free-form length
            d => numel * d.size_of(),
        };
        assert_eq!(
            e.bytes.len(),
            expect,
            "entry '{}' byte length mismatch",
            e.name
        );
        while payload.len() % ALIGN != 0 {
            payload.push(0);
        }
        let offset = payload.len();
        payload.extend_from_slice(&e.bytes);
        tensor_json.insert(
            e.name.clone(),
            Json::obj(vec![
                ("dtype", Json::str(e.dtype.name())),
                ("shape", Json::usizes(&e.shape)),
                ("offset", Json::num(offset as f64)),
                ("nbytes", Json::num(e.bytes.len() as f64)),
            ]),
        );
    }
    let mut header = BTreeMap::new();
    header.insert(
        "meta".to_string(),
        Json::Obj(
            meta.iter()
                .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                .collect(),
        ),
    );
    if let Some(c) = config {
        header.insert("config".to_string(), c.clone());
    }
    header.insert("tensors".to_string(), Json::Obj(tensor_json));
    let header_bytes = Json::Obj(header).to_string().into_bytes();

    let mut out = Vec::with_capacity(16 + header_bytes.len() + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(header_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(&header_bytes);
    out.extend_from_slice(&payload);
    out
}

/// Parse SQTZ bytes.
pub fn from_bytes(data: &[u8]) -> Result<Container> {
    if data.len() < 16 || &data[0..4] != MAGIC {
        bail!("not an SQTZ file (bad magic)");
    }
    let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported SQTZ version {version}");
    }
    let hlen = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
    if data.len() < 16 + hlen {
        bail!("truncated header");
    }
    let header = Json::parse(
        std::str::from_utf8(&data[16..16 + hlen]).context("header not UTF-8")?,
    )?;
    let payload = &data[16 + hlen..];

    let mut meta = BTreeMap::new();
    if let Some(m) = header.get("meta").and_then(|m| m.as_obj()) {
        for (k, v) in m {
            meta.insert(
                k.clone(),
                v.as_str()
                    .ok_or_else(|| anyhow!("meta '{k}' not a string"))?
                    .to_string(),
            );
        }
    }
    let config = header.get("config").cloned();

    let mut tensors = BTreeMap::new();
    let tj = header
        .req("tensors")?
        .as_obj()
        .ok_or_else(|| anyhow!("'tensors' not an object"))?;
    for (name, spec) in tj {
        let dtype = DType::parse(
            spec.req("dtype")?
                .as_str()
                .ok_or_else(|| anyhow!("dtype not a string"))?,
        )?;
        let shape = spec
            .req("shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("bad shape for '{name}'"))?;
        let offset = spec
            .req("offset")?
            .as_usize()
            .ok_or_else(|| anyhow!("bad offset"))?;
        let nbytes = spec
            .req("nbytes")?
            .as_usize()
            .ok_or_else(|| anyhow!("bad nbytes"))?;
        if offset + nbytes > payload.len() {
            bail!(
                "tensor '{name}' [{offset}..{}) exceeds payload {}",
                offset + nbytes,
                payload.len()
            );
        }
        tensors.insert(
            name.clone(),
            (dtype, shape, payload[offset..offset + nbytes].to_vec()),
        );
    }
    Ok(Container {
        meta,
        config,
        tensors,
    })
}

/// Write SQTZ to a file (atomically via a temp sibling).
pub fn write_file(
    path: impl AsRef<Path>,
    entries: &[Entry],
    meta: &BTreeMap<String, String>,
    config: Option<&Json>,
) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let bytes = to_bytes(entries, meta, config);
    let tmp = path.with_extension("sqtz.tmp");
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    Ok(())
}

/// Read SQTZ from a file.
pub fn read_file(path: impl AsRef<Path>) -> Result<Container> {
    let data =
        fs::read(path.as_ref()).with_context(|| format!("reading {}", path.as_ref().display()))?;
    from_bytes(&data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Tensor, TensorI8};

    fn sample_entries() -> Vec<Entry> {
        vec![
            Entry::f32("a", &Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.])),
            Entry::i8("b", &TensorI8::new(&[4], vec![-8, 0, 7, 1])),
            Entry::u8("c", vec![5], vec![0xAB, 0xCD, 0x01, 0x02, 0x03]),
        ]
    }

    #[test]
    fn roundtrip_in_memory() {
        let meta = BTreeMap::from([("k".to_string(), "v".to_string())]);
        let cfg = Json::obj(vec![("d_model", Json::num(32.0))]);
        let bytes = to_bytes(&sample_entries(), &meta, Some(&cfg));
        let c = from_bytes(&bytes).unwrap();
        assert_eq!(c.meta.get("k").unwrap(), "v");
        assert_eq!(
            c.config.as_ref().unwrap().get("d_model").unwrap().as_usize(),
            Some(32)
        );
        let a = c.f32("a").unwrap();
        assert_eq!(a.shape(), &[2, 3]);
        assert_eq!(a.data()[4], 5.0);
        assert_eq!(c.i8("b").unwrap().data(), &[-8, 0, 7, 1]);
        let (shape, raw) = c.u8("c").unwrap();
        assert_eq!(shape, &[5]);
        assert_eq!(raw, &[0xAB, 0xCD, 0x01, 0x02, 0x03]);
    }

    #[test]
    fn offsets_are_aligned() {
        let bytes = to_bytes(&sample_entries(), &BTreeMap::new(), None);
        let c = from_bytes(&bytes).unwrap();
        // Check by parsing header manually through the container API: the
        // payload copies are correct, which the roundtrip already checks;
        // verify alignment via the raw header.
        let hlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let header = Json::parse(std::str::from_utf8(&bytes[16..16 + hlen]).unwrap()).unwrap();
        for (_, spec) in header.get("tensors").unwrap().as_obj().unwrap() {
            let off = spec.get("offset").unwrap().as_usize().unwrap();
            assert_eq!(off % ALIGN, 0, "offset {off} unaligned");
        }
        assert!(c.contains("a") && !c.contains("zzz"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("sqtz_test");
        let path = dir.join("x.sqtz");
        write_file(&path, &sample_entries(), &BTreeMap::new(), None).unwrap();
        let c = read_file(&path).unwrap();
        assert_eq!(c.names().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corruption() {
        let bytes = to_bytes(&sample_entries(), &BTreeMap::new(), None);
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad).is_err());
        // Bad version.
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(from_bytes(&bad).is_err());
        // Truncated payload.
        let bad = &bytes[..bytes.len() - 4];
        assert!(from_bytes(bad).is_err());
        // Truncated header.
        assert!(from_bytes(&bytes[..20]).is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let bytes = to_bytes(&sample_entries(), &BTreeMap::new(), None);
        let c = from_bytes(&bytes).unwrap();
        assert!(c.f32("b").is_err());
        assert!(c.i8("a").is_err());
        assert!(c.u8("a").is_err());
        assert!(c.f32("missing").is_err());
    }
}
