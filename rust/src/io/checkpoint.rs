//! Checkpoint (FP model) save/load on top of the SQTZ container.
//!
//! The trained eval checkpoint is produced by `python/compile/train.py`
//! with the mirrored writer in `python/compile/sqtz.py`; golden-file
//! tests in both languages pin the byte format.

use std::collections::BTreeMap;
use std::path::Path;

use crate::model::{Checkpoint, PicoLlamaConfig};

use super::{read_file, write_file, Entry};
use anyhow::{anyhow, Result};

/// Save a checkpoint (config embedded in the header).
pub fn save_checkpoint(path: impl AsRef<Path>, ck: &Checkpoint) -> Result<()> {
    let entries: Vec<Entry> = ck
        .tensors
        .iter()
        .map(|(name, t)| Entry::f32(name.clone(), t))
        .collect();
    write_file(path, &entries, &ck.meta, Some(&ck.config.to_json()))
}

/// Load a checkpoint and validate it against its embedded config.
pub fn load_checkpoint(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let c = read_file(path)?;
    let config = PicoLlamaConfig::from_json(
        c.config
            .as_ref()
            .ok_or_else(|| anyhow!("checkpoint missing model config"))?,
    )?;
    let mut tensors = BTreeMap::new();
    for name in c.names() {
        tensors.insert(name.to_string(), c.f32(name)?);
    }
    let ck = Checkpoint {
        config,
        tensors,
        meta: c.meta,
    };
    ck.validate()?;
    Ok(ck)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = PicoLlamaConfig::test();
        let mut ck = Checkpoint::random_init(&cfg, 5);
        ck.meta.insert("trained_steps".into(), "0".into());
        let dir = std::env::temp_dir().join("sqtz_ckpt_test");
        let path = dir.join("m.sqtz");
        save_checkpoint(&path, &ck).unwrap();
        let back = load_checkpoint(&path).unwrap();
        assert_eq!(back.config, ck.config);
        assert_eq!(back.meta.get("trained_steps").unwrap(), "0");
        for (name, t) in &ck.tensors {
            assert_eq!(back.tensors.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_invalid_shapes() {
        // Write a container whose tensor shapes do not match the config.
        let cfg = PicoLlamaConfig::test();
        let ck = Checkpoint::random_init(&cfg, 1);
        let mut entries: Vec<Entry> = ck
            .tensors
            .iter()
            .map(|(n, t)| Entry::f32(n.clone(), t))
            .collect();
        // Corrupt one shape.
        entries[0] = Entry::f32(
            entries[0].name.clone(),
            &crate::tensor::Tensor::zeros(&[1, 1]),
        );
        let dir = std::env::temp_dir().join("sqtz_ckpt_bad");
        let path = dir.join("bad.sqtz");
        super::super::write_file(&path, &entries, &ck.meta, Some(&cfg.to_json())).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
