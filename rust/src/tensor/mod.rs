//! A small dense-tensor library (ndarray-lite) — the numeric substrate for
//! the quantization toolchain. Row-major, owned storage, f32 and i8
//! element types, with exactly the operations the pipeline needs:
//! construction, views over 2-D matrices, matmul, elementwise maps,
//! reductions and (de)serialization helpers.
//!
//! Deliberately *not* a general autodiff/NDArray framework: training runs
//! in JAX at build time; this crate only transforms and executes weights.

mod matmul;

pub use matmul::{matmul, matmul_into};

use anyhow::{bail, Result};

/// Element dtype of a stored tensor (the SQTZ container supports these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    /// Raw bytes (bit-packed INT4/INT2 planes).
    U8,
    I32,
}

impl DType {
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I8 => "i8",
            DType::U8 => "u8",
            DType::I32 => "i32",
        }
    }

    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" | "float32" => DType::F32,
            "i8" | "int8" => DType::I8,
            "u8" | "uint8" => DType::U8,
            "i32" | "int32" => DType::I32,
            _ => bail!("unknown dtype '{s}'"),
        })
    }
}

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_vec(data: Vec<f32>) -> Tensor {
        let n = data.len();
        Tensor::new(&[n], data)
    }

    /// Identity matrix [n, n].
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    // -- Introspection ----------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows / cols for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() on non-matrix");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() on non-matrix");
        self.shape[1]
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[r * self.shape[1] + c]
    }

    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let c_len = self.shape[1];
        self.data[r * c_len + c] = v;
    }

    /// Row slice of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    // -- Transforms -------------------------------------------------------

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?} size mismatch",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Transposed copy of a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(&[c, r], out)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Elementwise binary op; shapes must match exactly.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// In-place `self += other`.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    // -- Reductions -------------------------------------------------------

    pub fn min(&self) -> f32 {
        self.data.iter().cloned().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    /// Frobenius / L2 norm.
    pub fn norm(&self) -> f64 {
        self.data
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    pub fn count(&self, pred: impl Fn(f32) -> bool) -> usize {
        self.data.iter().filter(|&&x| pred(x)).count()
    }

    // -- Comparisons ------------------------------------------------------

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(&a, &b)| (a - b).abs() <= atol || (a.is_nan() && b.is_nan()))
    }

    // -- Bytes ------------------------------------------------------------

    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn from_le_bytes(shape: &[usize], bytes: &[u8]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("byte length {} != 4*{}", bytes.len(), n);
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor::new(shape, data))
    }
}

/// Dense row-major i8 tensor — quantized planes.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI8 {
    shape: Vec<usize>,
    data: Vec<i8>,
}

impl TensorI8 {
    pub fn new(shape: &[usize], data: Vec<i8>) -> TensorI8 {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI8 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> TensorI8 {
        TensorI8 {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[i8] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i8] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<i8> {
        self.data
    }

    /// Widen to f32 (no dequantization — raw integer values).
    pub fn to_f32(&self) -> Tensor {
        Tensor::new(
            &self.shape,
            self.data.iter().map(|&v| v as f32).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn eye_and_transpose() {
        let i = Tensor::eye(3);
        assert_eq!(i.transpose(), i);
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at2(2, 1), 6.0);
        assert_eq!(tt.transpose(), t);
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::from_vec(vec![1., 2., 3.]);
        let b = Tensor::from_vec(vec![4., 5., 6.]);
        assert_eq!(a.add(&b).data(), &[5., 7., 9.]);
        assert_eq!(b.sub(&a).data(), &[3., 3., 3.]);
        assert_eq!(a.scale(2.0).data(), &[2., 4., 6.]);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c.data(), &[5., 7., 9.]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(vec![-3., 1., 2.]);
        assert_eq!(t.min(), -3.0);
        assert_eq!(t.max(), 2.0);
        assert_eq!(t.abs_max(), 3.0);
        assert_eq!(t.sum(), 0.0);
        assert_eq!(t.mean(), 0.0);
        assert!((t.norm() - (14.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(t.count(|x| x > 0.0), 2);
    }

    #[test]
    fn byte_roundtrip() {
        let t = Tensor::new(&[2, 2], vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
        let b = t.to_le_bytes();
        let back = Tensor::from_le_bytes(&[2, 2], &b).unwrap();
        assert_eq!(back, t);
        assert!(Tensor::from_le_bytes(&[3], &b).is_err());
    }

    #[test]
    fn allclose_tolerates() {
        let a = Tensor::from_vec(vec![1.0, 2.0]);
        let b = Tensor::from_vec(vec![1.0 + 1e-6, 2.0 - 1e-6]);
        assert!(a.allclose(&b, 1e-5));
        assert!(!a.allclose(&b, 1e-8));
    }

    #[test]
    fn i8_tensor() {
        let t = TensorI8::new(&[2, 2], vec![-128, -1, 0, 127]);
        assert_eq!(t.to_f32().data(), &[-128., -1., 0., 127.]);
    }

    #[test]
    fn dtype_parse_roundtrip() {
        for d in [DType::F32, DType::I8, DType::U8, DType::I32] {
            assert_eq!(DType::parse(d.name()).unwrap(), d);
        }
        assert!(DType::parse("f64").is_err());
    }
}
