//! Cache-blocked f32 matrix multiplication.
//!
//! Used by the CPU-side reference paths (GPTQ-lite Hessian accumulation,
//! activation-split calibration, functional-equivalence checks). The PJRT
//! runtime executes the *model's* matmuls; this implementation only has to
//! be correct and respectably fast on one core.
//!
//! Strategy: i-k-j loop order (unit-stride inner loop over B's row),
//! blocked over k to keep a B-panel hot in L1/L2, with 4-wide manual
//! accumulation to let LLVM autovectorize.

use super::Tensor;

const KC: usize = 256; // k-panel

/// C = A · B for A:[m,k], B:[k,n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// Raw-slice matmul: c[m,n] += a[m,k] · b[k,n] (c must be zeroed by the
/// caller if a fresh product is wanted).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    // Split layers are ~2/3 zeros; skipping is a large win
                    // and exact (0 * x == 0 for finite x; weights are finite).
                    continue;
                }
                let brow = &b[kk * n..kk * n + n];
                // 4-wide unrolled AXPY, autovectorizes to SIMD.
                let chunks = n / 4 * 4;
                let mut j = 0;
                while j < chunks {
                    crow[j] += aik * brow[j];
                    crow[j + 1] += aik * brow[j + 1];
                    crow[j + 2] += aik * brow[j + 2];
                    crow[j + 3] += aik * brow[j + 3];
                    j += 4;
                }
                while j < n {
                    crow[j] += aik * brow[j];
                    j += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a.at2(i, kk) as f64 * b.at2(kk, j) as f64;
                }
                c.set2(i, j, s as f32);
            }
        }
        c
    }

    #[test]
    fn small_known_product() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(matmul(&a, &b).data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn identity_is_neutral() {
        let mut r = Rng::new(1);
        let mut data = vec![0.0f32; 6 * 6];
        r.fill_normal(&mut data, 0.0, 1.0);
        let a = Tensor::new(&[6, 6], data);
        assert!(matmul(&a, &Tensor::eye(6)).allclose(&a, 1e-6));
        assert!(matmul(&Tensor::eye(6), &a).allclose(&a, 1e-6));
    }

    #[test]
    fn matches_naive_on_random_rect() {
        let mut r = Rng::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 300, 31)] {
            let mut ad = vec![0.0f32; m * k];
            let mut bd = vec![0.0f32; k * n];
            r.fill_normal(&mut ad, 0.0, 1.0);
            r.fill_normal(&mut bd, 0.0, 1.0);
            let a = Tensor::new(&[m, k], ad);
            let b = Tensor::new(&[k, n], bd);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(
                got.allclose(&want, 1e-3),
                "mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn sparse_skip_is_exact() {
        // Matrices with many zeros (split-layer shape) must give identical
        // results to the dense path.
        let mut r = Rng::new(3);
        let (m, k, n) = (8, 40, 8);
        let mut ad = vec![0.0f32; m * k];
        for v in ad.iter_mut() {
            if r.uniform() < 0.3 {
                *v = r.normal_f32(0.0, 1.0);
            }
        }
        let mut bd = vec![0.0f32; k * n];
        r.fill_normal(&mut bd, 0.0, 1.0);
        let a = Tensor::new(&[m, k], ad);
        let b = Tensor::new(&[k, n], bd);
        assert!(matmul(&a, &b).allclose(&naive(&a, &b), 1e-4));
    }

    #[test]
    fn empty_dims() {
        let a = Tensor::zeros(&[0, 3]);
        let b = Tensor::zeros(&[3, 2]);
        assert_eq!(matmul(&a, &b).shape(), &[0, 2]);
    }

    #[test]
    #[should_panic]
    fn inner_dim_mismatch_panics() {
        matmul(&Tensor::zeros(&[2, 3]), &Tensor::zeros(&[4, 2]));
    }
}
