//! Outlier Channel Splitting (OCS) — the Zhao et al. 2019 baseline the
//! paper's §2.3 compares against.
//!
//! OCS mitigates outliers by *duplicating* the input channel that holds
//! the largest-magnitude weight and *halving* both copies: the layer's
//! function is preserved (the duplicated activation feeds both halves),
//! while the layer's absmax shrinks. Repeating this with an expansion
//! budget ε (fraction of extra channels) reduces the quantization range
//! at the cost of a wider layer.
//!
//! We evaluate OCS the same way we evaluate SplitQuantV2: by the
//! *effective* dequantized weight — quantize the expanded matrix, then
//! fold duplicated columns back together. This is exactly the numerics an
//! OCS-expanded network would exhibit; the structural expansion (the
//! previous layer emitting duplicated outputs) is captured by the fold.

use crate::quant::{self, Bits};
use crate::tensor::Tensor;

/// Result of an OCS expansion of a `[out, in]` weight matrix.
#[derive(Clone, Debug)]
pub struct OcsExpansion {
    /// Expanded matrix `[out, in + extra]`.
    pub expanded: Tensor,
    /// For each expanded column, the original column it came from.
    pub origin: Vec<usize>,
    pub extra_cols: usize,
}

/// Expand by duplicate-and-halve until `extra = ceil(ε·in)` extra columns
/// exist. Each step targets the column containing the current global
/// absmax (Zhao et al.'s weight-split criterion).
pub fn ocs_expand(w: &Tensor, expand_ratio: f64) -> OcsExpansion {
    assert_eq!(w.ndim(), 2, "OCS requires a matrix");
    let (rows, cols) = (w.rows(), w.cols());
    let extra = ((cols as f64 * expand_ratio).ceil() as usize).min(cols * 4);
    // Column-major working copy for cheap column ops.
    let mut columns: Vec<Vec<f32>> = (0..cols)
        .map(|c| (0..rows).map(|r| w.at2(r, c)).collect())
        .collect();
    let mut origin: Vec<usize> = (0..cols).collect();

    for _ in 0..extra {
        // Column with the global max |w|.
        let (mut best_col, mut best_val) = (0usize, -1.0f32);
        for (ci, col) in columns.iter().enumerate() {
            for &v in col {
                if v.abs() > best_val {
                    best_val = v.abs();
                    best_col = ci;
                }
            }
        }
        // Halve in place and append the duplicate.
        for v in columns[best_col].iter_mut() {
            *v *= 0.5;
        }
        let dup = columns[best_col].clone();
        let org = origin[best_col];
        columns.push(dup);
        origin.push(org);
    }

    let ncols = columns.len();
    let mut data = vec![0.0f32; rows * ncols];
    for (ci, col) in columns.iter().enumerate() {
        for r in 0..rows {
            data[r * ncols + ci] = col[r];
        }
    }
    OcsExpansion {
        expanded: Tensor::new(&[rows, ncols], data),
        origin,
        extra_cols: extra,
    }
}

impl OcsExpansion {
    /// Fold an expanded-shape matrix back to the original shape by summing
    /// duplicated columns into their origin.
    pub fn fold(&self, m: &Tensor) -> Tensor {
        assert_eq!(m.shape(), self.expanded.shape());
        let rows = m.rows();
        let orig_cols = self.origin.iter().copied().max().unwrap() + 1;
        let mut out = Tensor::zeros(&[rows, orig_cols]);
        for (ci, &oc) in self.origin.iter().enumerate() {
            for r in 0..rows {
                let v = out.at2(r, oc) + m.at2(r, ci);
                out.set2(r, oc, v);
            }
        }
        out
    }

    /// Exact functional check: fold(expanded) == original.
    pub fn reconstruct(&self) -> Tensor {
        self.fold(&self.expanded)
    }
}

/// OCS fake-quantization: the effective weight after expanding, linearly
/// quantizing the expanded matrix, and folding back.
pub fn ocs_fake_quantize(w: &Tensor, expand_ratio: f64, bits: Bits) -> Tensor {
    let exp = ocs_expand(w, expand_ratio);
    let q = quant::fake_quantize(&exp.expanded, bits);
    exp.fold(&q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    fn outlier_matrix(seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut data: Vec<f32> = (0..32 * 32).map(|_| r.normal_f32(0.0, 0.05)).collect();
        data[5] = 4.0;
        data[777] = -3.5;
        Tensor::new(&[32, 32], data)
    }

    #[test]
    fn expansion_preserves_function() {
        let w = outlier_matrix(1);
        let exp = ocs_expand(&w, 0.05);
        assert!(exp.extra_cols > 0);
        assert_eq!(exp.expanded.cols(), 32 + exp.extra_cols);
        let rec = exp.reconstruct();
        assert!(
            rec.allclose(&w, 1e-6),
            "fold(expand(W)) must equal W"
        );
    }

    #[test]
    fn halving_shrinks_absmax() {
        let w = outlier_matrix(2);
        let exp = ocs_expand(&w, 0.1);
        assert!(exp.expanded.abs_max() < w.abs_max());
    }

    #[test]
    fn ocs_reduces_quant_error_on_outliers() {
        let w = outlier_matrix(3);
        let base = quant::fake_quantize(&w, Bits::Int4);
        let ocs = ocs_fake_quantize(&w, 0.1, Bits::Int4);
        let mse_base = mse(w.data(), base.data());
        let mse_ocs = mse(w.data(), ocs.data());
        assert!(
            mse_ocs < mse_base,
            "ocs {mse_ocs} should beat baseline {mse_base}"
        );
    }

    #[test]
    fn splitquant_beats_ocs_without_outliers() {
        // §2.3: SplitQuantV2 improves resolution even absent outliers,
        // OCS primarily addresses outliers.
        let mut r = Rng::new(4);
        let w = Tensor::new(
            &[24, 24],
            (0..576).map(|_| r.normal_f32(0.0, 1.0)).collect(),
        );
        let ocs = ocs_fake_quantize(&w, 0.05, Bits::Int4);
        let sq = crate::split::split_fake_quantize(
            &w,
            &crate::split::SplitConfig::default(),
            Bits::Int4,
        );
        let mse_ocs = mse(w.data(), ocs.data());
        let mse_sq = mse(w.data(), sq.data());
        assert!(
            mse_sq < mse_ocs,
            "splitquant {mse_sq} should beat ocs {mse_ocs} on gaussians"
        );
    }

    #[test]
    fn zero_ratio_is_identity() {
        let w = outlier_matrix(5);
        let exp = ocs_expand(&w, 0.0);
        assert_eq!(exp.extra_cols, 0);
        assert_eq!(exp.expanded.data(), w.data());
    }
}
