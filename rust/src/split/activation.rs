//! Activation splitting with a calibration dataset — the paper's §5
//! future-work extension, implemented.
//!
//! When calibration data *is* available, the same clustering idea applies
//! to activations: simulated activation values from a calibration batch
//! are clustered into k groups; at inference each activation value is
//! quantized with the parameters of its cluster (selected by the cluster
//! boundaries — the "masking layers" of §5). This is piecewise linear
//! quantization with data-derived breakpoints; resolution inside the
//! dense cluster improves exactly as for weights.

use crate::kmeans::{kmeans_auto, Clustering1D};
use crate::quant::{Bits, QuantParams};

/// Calibrated piecewise activation quantizer.
#[derive(Clone, Debug)]
pub struct ActivationSplitter {
    pub clustering: Clustering1D,
    pub params: Vec<QuantParams>,
    pub bits: Bits,
    /// Calibration range, used to clamp unseen values.
    pub cal_min: f32,
    pub cal_max: f32,
}

impl ActivationSplitter {
    /// Calibrate from sampled activation values.
    pub fn calibrate(samples: &[f32], k: usize, bits: Bits) -> ActivationSplitter {
        assert!(!samples.is_empty(), "calibration requires samples");
        let clustering = kmeans_auto(samples, k);
        let cal_min = samples.iter().cloned().fold(f32::INFINITY, f32::min);
        let cal_max = samples.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let ranges = clustering.cluster_ranges(cal_min as f64, cal_max as f64);
        let params = ranges
            .iter()
            .map(|&(lo, hi)| QuantParams::from_range(bits, lo as f32, hi as f32))
            .collect();
        ActivationSplitter {
            clustering,
            params,
            bits,
            cal_min,
            cal_max,
        }
    }

    /// Cluster index + quantized level for a value (clamped to the
    /// calibration range, as all static activation quantizers must).
    #[inline]
    pub fn quantize(&self, x: f32) -> (usize, i8) {
        let x = x.clamp(self.cal_min, self.cal_max);
        let c = self.clustering.assign(x);
        (c, self.params[c].quantize(x))
    }

    /// Fake-quantize one value through the splitter.
    #[inline]
    pub fn fake_quantize(&self, x: f32) -> f32 {
        let (c, q) = self.quantize(x);
        self.params[c].dequantize(q)
    }

    /// Fake-quantize a slice (the masked-activation path applied densely).
    pub fn fake_quantize_all(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.fake_quantize(x)).collect()
    }
}

/// Baseline single-range activation quantizer (what you get without
/// splitting), for comparison in E9.
pub fn baseline_activation_quantizer(samples: &[f32], bits: Bits) -> QuantParams {
    let lo = samples.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = samples.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    QuantParams::from_range(bits, lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    /// GELU-ish activation distribution: mostly near zero, long positive
    /// tail (post-nonlinearity activations in transformers look like this).
    fn activation_samples(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n)
            .map(|_| {
                let x = r.normal_f32(0.0, 1.0);
                // softplus-like: small negatives, heavy positive tail
                if x > 0.0 {
                    x * x * 0.8
                } else {
                    0.1 * x
                }
            })
            .collect()
    }

    #[test]
    fn split_beats_single_range_on_skewed_activations() {
        let cal = activation_samples(1, 20_000);
        let test = activation_samples(2, 5_000);
        let splitter = ActivationSplitter::calibrate(&cal, 3, Bits::Int4);
        let baseline = baseline_activation_quantizer(&cal, Bits::Int4);

        let split_q = splitter.fake_quantize_all(&test);
        let base_q: Vec<f32> = test
            .iter()
            .map(|&x| {
                baseline.dequantize(baseline.quantize(x.clamp(
                    splitter.cal_min,
                    splitter.cal_max,
                )))
            })
            .collect();
        let mse_split = mse(&test, &split_q);
        let mse_base = mse(&test, &base_q);
        assert!(
            mse_split < mse_base * 0.6,
            "split {mse_split} vs baseline {mse_base}"
        );
    }

    #[test]
    fn quantize_clamps_unseen_values() {
        let cal = vec![0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0];
        let s = ActivationSplitter::calibrate(&cal, 2, Bits::Int8);
        // Far outside calibration: clamps rather than exploding.
        let v = s.fake_quantize(100.0);
        assert!(v <= 5.0 + 0.1);
        let v = s.fake_quantize(-100.0);
        assert!(v >= -0.1);
    }

    #[test]
    fn roundtrip_error_within_cluster_step() {
        let cal = activation_samples(3, 10_000);
        let s = ActivationSplitter::calibrate(&cal, 3, Bits::Int8);
        for &x in cal.iter().take(500) {
            let (c, _) = s.quantize(x);
            let err = (x - s.fake_quantize(x)).abs() as f64;
            assert!(err <= 0.5 * s.params[c].step() + 1e-6);
        }
    }

    #[test]
    fn k1_equals_baseline() {
        let cal = activation_samples(4, 5_000);
        let s = ActivationSplitter::calibrate(&cal, 1, Bits::Int4);
        let b = baseline_activation_quantizer(&cal, Bits::Int4);
        for &x in cal.iter().take(200) {
            let via_split = s.fake_quantize(x);
            let via_base = b.dequantize(b.quantize(x));
            assert!((via_split - via_base).abs() < 1e-6);
        }
    }
}
