//! Normalization folding — the paper's §3 note: "normalization layers
//! can be easily folded into the preceding linear or convolution layers
//! to simplify DNNs before applying SplitQuantV2."
//!
//! For an RMSNorm/LayerNorm-style gain γ applied *before* a linear layer
//! (`y = W(γ ⊙ x̂)`), the gain folds into the columns of W:
//! `W' = W · diag(γ)`; for a gain applied *after* (`y = γ ⊙ (Wx)`), it
//! folds into the rows. Folding widens some weight rows/columns — which
//! is exactly when the SplitQuantV2 clustering pays for itself, since
//! the widened values land in the outer clusters.

use crate::tensor::Tensor;

/// Fold a pre-norm gain γ (length = in_features) into `W[out, in]`:
/// returns `W · diag(γ)` so that `W' x̂ == W (γ ⊙ x̂)`.
pub fn fold_pre_gain(w: &Tensor, gamma: &Tensor) -> Tensor {
    assert_eq!(w.ndim(), 2);
    assert_eq!(gamma.len(), w.cols(), "gain length must equal in_features");
    let (rows, cols) = (w.rows(), w.cols());
    let mut out = w.clone();
    let g = gamma.data();
    for r in 0..rows {
        let row = &mut out.data_mut()[r * cols..(r + 1) * cols];
        for c in 0..cols {
            row[c] *= g[c];
        }
    }
    out
}

/// Fold a post-norm gain γ (length = out_features) into `W[out, in]`:
/// returns `diag(γ) · W` so that `W' x == γ ⊙ (W x)`.
pub fn fold_post_gain(w: &Tensor, gamma: &Tensor) -> Tensor {
    assert_eq!(w.ndim(), 2);
    assert_eq!(gamma.len(), w.rows(), "gain length must equal out_features");
    let (rows, cols) = (w.rows(), w.cols());
    let mut out = w.clone();
    let g = gamma.data();
    for r in 0..rows {
        let row = &mut out.data_mut()[r * cols..(r + 1) * cols];
        for v in row.iter_mut() {
            *v *= g[r];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Rng;

    fn rand(seed: u64, r_: usize, c: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut d = vec![0.0f32; r_ * c];
        rng.fill_normal(&mut d, 0.0, 1.0);
        Tensor::new(&[r_, c], d)
    }

    #[test]
    fn pre_gain_fold_is_function_preserving() {
        let w = rand(1, 6, 4);
        let gamma = rand(2, 1, 4).reshape(&[4]);
        let x = rand(3, 4, 3); // columns = 3 input vectors
        // y1 = W (diag(γ) x); y2 = (W diag(γ)) x — must match.
        let gx = {
            let mut m = x.clone();
            for r in 0..4 {
                for c in 0..3 {
                    m.set2(r, c, m.at2(r, c) * gamma.data()[r]);
                }
            }
            m
        };
        let y1 = matmul(&w, &gx);
        let y2 = matmul(&fold_pre_gain(&w, &gamma), &x);
        assert!(y1.allclose(&y2, 1e-5));
    }

    #[test]
    fn post_gain_fold_is_function_preserving() {
        let w = rand(4, 5, 4);
        let gamma = rand(5, 1, 5).reshape(&[5]);
        let x = rand(6, 4, 2);
        let y1 = {
            let mut m = matmul(&w, &x);
            for r in 0..5 {
                for c in 0..2 {
                    m.set2(r, c, m.at2(r, c) * gamma.data()[r]);
                }
            }
            m
        };
        let y2 = matmul(&fold_post_gain(&w, &gamma), &x);
        assert!(y1.allclose(&y2, 1e-5));
    }

    #[test]
    fn unit_gain_is_identity() {
        let w = rand(7, 6, 4);
        let ones = Tensor::full(&[4], 1.0);
        assert_eq!(fold_pre_gain(&w, &ones), w);
        let ones = Tensor::full(&[6], 1.0);
        assert_eq!(fold_post_gain(&w, &ones), w);
    }

    #[test]
    fn folding_widens_range_then_split_recovers() {
        // A spiky gain inflates some columns; baseline quantization
        // degrades, splitting isolates the inflated values.
        let w = rand(8, 16, 16).scale(0.05);
        let mut gd = vec![1.0f32; 16];
        gd[3] = 30.0;
        let gamma = Tensor::new(&[16], gd);
        let folded = fold_pre_gain(&w, &gamma);
        use crate::quant::{quant_mse, Bits};
        use crate::split::{split_fake_quantize, SplitConfig};
        let base = quant_mse(&folded, Bits::Int4);
        let eff = split_fake_quantize(&folded, &SplitConfig::default(), Bits::Int4);
        let split = crate::util::stats::mse(folded.data(), eff.data());
        assert!(split < base * 0.2, "split {split} vs base {base}");
    }
}
