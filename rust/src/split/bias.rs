//! Biased-linear-layer splitting — the paper's §3 "weights *and biases*
//! are partitioned using k-means clustering".
//!
//! picollama (like Llama) has bias-free linears, so the LLM pipeline
//! never exercises this; it exists for the general library contract
//! (conv/linear layers of CV models in the SplitQuant lineage do carry
//! biases). Semantics: the weight values and bias values are clustered
//! *jointly* (one shared value-space partition), each plane gets the
//! masked weights AND the masked bias of its cluster, and
//!
//!   y = Σⱼ (Wⱼ x + bⱼ)  ==  W x + b     (exact: masks are disjoint)
//!
//! so each plane's quantizer covers a narrow range for both its weights
//! and its bias entries.

use crate::quant::{self, Bits, QuantParams, QuantizedTensor};
use crate::tensor::Tensor;

use super::{QuantizedSplitLayer, SplitConfig, SplitLayer, Strategy};

/// A split biased layer: planes of weights + matching bias planes.
#[derive(Clone, Debug)]
pub struct SplitBiasedLayer {
    pub weights: SplitLayer,
    /// One bias plane per weight plane (same length as the bias).
    pub biases: Vec<Tensor>,
}

impl SplitBiasedLayer {
    pub fn k(&self) -> usize {
        self.weights.k()
    }

    /// Reconstruct (W, b) exactly.
    pub fn reconstruct(&self) -> (Tensor, Tensor) {
        let w = self.weights.reconstruct();
        let mut b = self.biases[0].clone();
        for p in &self.biases[1..] {
            b.add_assign(p);
        }
        (w, b)
    }
}

/// Split a biased linear layer with *joint* weight+bias clustering.
pub fn split_biased(w: &Tensor, bias: &Tensor, cfg: &SplitConfig) -> SplitBiasedLayer {
    assert_eq!(
        cfg.strategy,
        Strategy::MaskedSum,
        "bias splitting is defined for the masked-sum structure"
    );
    // Joint value pool: weights ++ bias.
    let mut pool = Vec::with_capacity(w.len() + bias.len());
    pool.extend_from_slice(w.data());
    pool.extend_from_slice(bias.data());
    let clustering = match cfg.dynamic_k {
        Some(d) => {
            let (k, mut tried) = crate::kmeans::choose_k(&pool, d.k_max, d.elbow);
            tried.swap_remove(k - 1)
        }
        None => crate::kmeans::kmeans_auto(&pool, cfg.k),
    };
    let k = clustering.k();
    let mut wplanes = vec![Tensor::zeros(w.shape()); k];
    for (i, &v) in w.data().iter().enumerate() {
        wplanes[clustering.assign(v)].data_mut()[i] = v;
    }
    let mut bplanes = vec![Tensor::zeros(bias.shape()); k];
    for (i, &v) in bias.data().iter().enumerate() {
        bplanes[clustering.assign(v)].data_mut()[i] = v;
    }
    SplitBiasedLayer {
        weights: SplitLayer {
            planes: wplanes,
            clustering,
            strategy: Strategy::MaskedSum,
        },
        biases: bplanes,
    }
}

/// Quantized biased split layer: each plane's weights and bias share the
/// plane's quantizer (ranges widened over both).
#[derive(Clone, Debug)]
pub struct QuantizedBiasedLayer {
    pub weights: QuantizedSplitLayer,
    pub biases: Vec<QuantizedTensor>,
}

impl QuantizedBiasedLayer {
    /// Effective (dequantized) (W, b).
    pub fn effective(&self) -> (Tensor, Tensor) {
        let w = self.weights.effective_weight();
        let mut b = self.biases[0].dequantize();
        for p in &self.biases[1..] {
            b.add_assign(&p.dequantize());
        }
        (w, b)
    }
}

/// Split + quantize a biased layer.
pub fn split_quantize_biased(
    w: &Tensor,
    bias: &Tensor,
    cfg: &SplitConfig,
    bits: Bits,
) -> QuantizedBiasedLayer {
    let sl = split_biased(w, bias, cfg);
    let mut qw = Vec::with_capacity(sl.k());
    let mut qb = Vec::with_capacity(sl.k());
    for (wp, bp) in sl.weights.planes.iter().zip(&sl.biases) {
        // Shared params across the plane's weights and bias values.
        let lo = wp.min().min(bp.min());
        let hi = wp.max().max(bp.max());
        let p = QuantParams::from_range(bits, lo, hi);
        let quantize = |t: &Tensor| QuantizedTensor {
            plane: crate::tensor::TensorI8::new(
                t.shape(),
                t.data().iter().map(|&x| p.quantize(x)).collect(),
            ),
            granularity: quant::Granularity::PerTensor,
            params: vec![p],
        };
        qw.push(quantize(wp));
        qb.push(quantize(bp));
    }
    QuantizedBiasedLayer {
        weights: QuantizedSplitLayer {
            planes: qw,
            clustering: sl.weights.clustering.clone(),
            strategy: Strategy::MaskedSum,
        },
        biases: qb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    fn layer(seed: u64) -> (Tensor, Tensor) {
        let mut r = Rng::new(seed);
        let mut wd: Vec<f32> = (0..32 * 24).map(|_| r.normal_f32(0.0, 0.05)).collect();
        wd[7] = 1.9;
        wd[300] = -2.2;
        let bd: Vec<f32> = (0..32).map(|_| r.normal_f32(0.0, 0.1)).collect();
        (Tensor::new(&[32, 24], wd), Tensor::from_vec(bd))
    }

    #[test]
    fn biased_split_reconstructs_exactly() {
        let (w, b) = layer(1);
        let sl = split_biased(&w, &b, &SplitConfig::default());
        assert_eq!(sl.k(), 3);
        let (rw, rb) = sl.reconstruct();
        assert_eq!(rw.data(), w.data());
        assert_eq!(rb.data(), b.data());
    }

    #[test]
    fn bias_values_partition_like_weights() {
        let (w, mut b) = layer(2);
        b.data_mut()[0] = 1.9; // bias outlier lands in the upper cluster
        let sl = split_biased(&w, &b, &SplitConfig::default());
        let upper = sl.k() - 1;
        assert_eq!(sl.biases[upper].data()[0], 1.9);
        // And it is zero in the other planes.
        for j in 0..upper {
            assert_eq!(sl.biases[j].data()[0], 0.0);
        }
    }

    #[test]
    fn quantized_biased_beats_baseline() {
        let (w, b) = layer(3);
        let q = split_quantize_biased(&w, &b, &SplitConfig::default(), Bits::Int4);
        let (ew, eb) = q.effective();
        let base_w = quant::fake_quantize(&w, Bits::Int4);
        let e_split = mse(w.data(), ew.data());
        let e_base = mse(w.data(), base_w.data());
        assert!(e_split < e_base * 0.3, "split {e_split} vs base {e_base}");
        // Bias error bounded by its plane's step.
        for (i, &v) in b.data().iter().enumerate() {
            let c = q.weights.clustering.assign(v);
            let step = q.biases[c].params[0].step();
            assert!(((v - eb.data()[i]) as f64).abs() <= 0.5 * step + 1e-6);
        }
    }

    #[test]
    fn zero_bias_stays_exact() {
        let (w, _) = layer(4);
        let b = Tensor::zeros(&[32]);
        let q = split_quantize_biased(&w, &b, &SplitConfig::default(), Bits::Int2);
        let (_, eb) = q.effective();
        assert_eq!(eb.data(), b.data(), "masked zeros must stay exact");
    }
}
