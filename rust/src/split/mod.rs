//! SplitQuantV2 — functionally-equivalent layer splitting for
//! quantization-resolution recovery (the paper's §3).
//!
//! For a weight tensor `W`, the scalar weight values are clustered into
//! k = 3 (lower / middle / upper) groups by **exact 1-D k-means**; the
//! layer is replaced by k parallel layers whose weight planes are the
//! cluster-masked copies of `W`:
//!
//! ```text
//!   W_j[p] = W[p]  if assign(W[p]) == j  else  0
//!   ⇒  ΣWⱼ == W  (bit-exact; each position nonzero in exactly one plane)
//!   ⇒  y = W₁x + W₂x + W₃x + b  ==  Wx + b  (up to FP summation order)
//! ```
//!
//! Each plane is then linearly quantized **independently**. Because each
//! plane's value range is only its cluster's range (outliers live alone in
//! the lower/upper planes), the scaling factor S of each plane is far
//! larger than the original layer's, and the quantization resolution of
//! the middle plane — which holds ~99% of the mass — improves by the ratio
//! of ranges. Masked zeros are exactly representable (see `quant`), so
//! they contribute no noise.
//!
//! Strategies:
//! * [`Strategy::MaskedSum`] — the paper's structure (Figure 1): k dense
//!   planes, outputs summed. Quantized size is k× the baseline plane
//!   (hence the paper's 3/8-of-FP32 figure for INT4, §5).
//! * [`Strategy::RowWise`] — ablation: rows (output channels) are
//!   partitioned by row-absmax clustering; equivalent to splitting into k
//!   smaller layers + concat, keeping size at 1/8 but with coarser
//!   per-cluster ranges.
//!
//! Submodules: [`ocs`] (Outlier Channel Splitting baseline, §2.3),
//! [`activation`] (calibrated activation splitting, §5 future work).

pub mod activation;
pub mod bias;
pub mod fold;
pub mod ocs;

use crate::kmeans::{self, Clustering1D};
use crate::quant::{self, Bits, Granularity, QuantParams, QuantizedTensor};
use crate::tensor::{Tensor, TensorI8};

/// How rows/values are partitioned into split layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Cluster scalar weight values; k dense masked planes summed (paper).
    MaskedSum,
    /// Cluster rows by absmax; planes hold disjoint row sets (ablation).
    RowWise,
}

/// Dynamic per-layer cluster-count selection (§5 future work).
#[derive(Clone, Copy, Debug)]
pub struct DynamicK {
    pub k_max: usize,
    /// Minimum relative inertia improvement to accept k over k−1.
    pub elbow: f64,
}

impl Default for DynamicK {
    fn default() -> Self {
        Self {
            k_max: 4,
            elbow: 0.25,
        }
    }
}

/// Configuration of the SplitQuantV2 preprocessing pass.
#[derive(Clone, Debug)]
pub struct SplitConfig {
    /// Number of clusters (the paper fixes 3; 2 trades accuracy for size).
    pub k: usize,
    pub strategy: Strategy,
    /// Skip layers with fewer elements (embedding/norm layers are excluded
    /// by *kind* in the model pipeline; this additionally guards tiny
    /// tensors where splitting cannot pay for its overhead).
    pub min_elems: usize,
    /// If set, choose k per layer by inertia elbow instead of `k`.
    pub dynamic_k: Option<DynamicK>,
}

impl Default for SplitConfig {
    fn default() -> Self {
        Self {
            k: 3,
            strategy: Strategy::MaskedSum,
            min_elems: 64,
            dynamic_k: None,
        }
    }
}

impl SplitConfig {
    pub fn with_k(k: usize) -> Self {
        Self {
            k,
            ..Default::default()
        }
    }
}

/// A split layer in floating point: masked planes that sum to the
/// original tensor. Produced by [`split_tensor`]; used by the functional-
/// equivalence checks and by FP export.
#[derive(Clone, Debug)]
pub struct SplitLayer {
    pub planes: Vec<Tensor>,
    pub clustering: Clustering1D,
    pub strategy: Strategy,
}

impl SplitLayer {
    pub fn k(&self) -> usize {
        self.planes.len()
    }

    /// Reconstruct the original tensor (exact for MaskedSum/RowWise).
    pub fn reconstruct(&self) -> Tensor {
        let mut acc = self.planes[0].clone();
        for p in &self.planes[1..] {
            acc.add_assign(p);
        }
        acc
    }
}

/// A split layer in quantized form: one independently-quantized plane per
/// cluster. This is what the packed model container stores and what the
/// runtime's `split_matmul` kernel consumes.
#[derive(Clone, Debug)]
pub struct QuantizedSplitLayer {
    pub planes: Vec<QuantizedTensor>,
    pub clustering: Clustering1D,
    pub strategy: Strategy,
}

impl QuantizedSplitLayer {
    pub fn k(&self) -> usize {
        self.planes.len()
    }

    /// The dequantized effective weight: Σⱼ dequant(Qⱼ). Masked zeros
    /// dequantize to exactly 0, so position p carries exactly its own
    /// cluster's quantization of W[p].
    pub fn effective_weight(&self) -> Tensor {
        let mut acc = self.planes[0].dequantize();
        for p in &self.planes[1..] {
            acc.add_assign(&p.dequantize());
        }
        acc
    }

    /// Total packed bytes of all planes (E4 size accounting).
    pub fn packed_len(&self) -> usize {
        self.planes.iter().map(|p| p.packed_len()).sum()
    }
}

/// Choose the clustering for a tensor under a config.
fn cluster_values(values: &[f32], cfg: &SplitConfig) -> Clustering1D {
    match cfg.dynamic_k {
        Some(d) => {
            let (k, mut tried) = kmeans::choose_k(values, d.k_max, d.elbow);
            tried.swap_remove(k - 1)
        }
        None => kmeans::kmeans_auto(values, cfg.k),
    }
}

/// Per-row representative statistic for the RowWise strategy.
fn row_absmax(w: &Tensor) -> Vec<f32> {
    (0..w.rows())
        .map(|r| w.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
        .collect()
}

/// Scatter whole rows of `w` into k planes by their clustered statistic —
/// the single RowWise partitioning loop shared by [`split_tensor`] and
/// [`split_quantize_clustered`].
fn scatter_rows(w: &Tensor, stats: &[f32], clustering: &Clustering1D) -> Vec<Tensor> {
    let mut planes = vec![Tensor::zeros(w.shape()); clustering.k()];
    let cols = w.cols();
    for r in 0..w.rows() {
        let c = clustering.assign(stats[r]);
        planes[c].data_mut()[r * cols..(r + 1) * cols].copy_from_slice(w.row(r));
    }
    planes
}

/// Split a tensor into FP masked planes (Figure 1 structure).
///
/// Returns a single-plane `SplitLayer` (identity split) when the tensor is
/// smaller than `cfg.min_elems` or the clustering degenerates to k=1.
pub fn split_tensor(w: &Tensor, cfg: &SplitConfig) -> SplitLayer {
    if w.len() < cfg.min_elems {
        return identity_split(w, cfg.strategy);
    }
    match cfg.strategy {
        Strategy::MaskedSum => {
            let clustering = cluster_values(w.data(), cfg);
            let k = clustering.k();
            if k <= 1 {
                return identity_split(w, cfg.strategy);
            }
            let mut planes = vec![Tensor::zeros(w.shape()); k];
            for (i, &v) in w.data().iter().enumerate() {
                let c = clustering.assign(v);
                planes[c].data_mut()[i] = v;
            }
            SplitLayer {
                planes,
                clustering,
                strategy: Strategy::MaskedSum,
            }
        }
        Strategy::RowWise => {
            assert_eq!(w.ndim(), 2, "RowWise split requires a matrix");
            let stats = row_absmax(w);
            let clustering = cluster_values(&stats, cfg);
            if clustering.k() <= 1 {
                return identity_split(w, cfg.strategy);
            }
            let planes = scatter_rows(w, &stats, &clustering);
            SplitLayer {
                planes,
                clustering,
                strategy: Strategy::RowWise,
            }
        }
    }
}

fn identity_split(w: &Tensor, strategy: Strategy) -> SplitLayer {
    SplitLayer {
        planes: vec![w.clone()],
        clustering: Clustering1D {
            centroids: vec![w.mean()],
            boundaries: vec![],
            inertia: 0.0,
            sizes: vec![w.len() as f64],
            member_ranges: Some(vec![(w.min(), w.max())]),
        },
        strategy,
    }
}

/// Quantize an FP split layer: each plane independently per-tensor.
pub fn quantize_split(sl: &SplitLayer, bits: Bits) -> QuantizedSplitLayer {
    QuantizedSplitLayer {
        planes: sl
            .planes
            .iter()
            .map(|p| quant::quantize_per_tensor(p, bits))
            .collect(),
        clustering: sl.clustering.clone(),
        strategy: sl.strategy,
    }
}

/// **Phase 1 of the fused hot path**: the clustering decision for `w` —
/// over scalar weight values for [`Strategy::MaskedSum`], over row-absmax
/// statistics for [`Strategy::RowWise`]. Exposed separately so the
/// layer-pipeline engine can schedule and time the cluster stage of each
/// layer's work unit independently of the quantize stage;
/// [`split_quantize`] is exactly `split_quantize_clustered(w,
/// cluster_weights(w, cfg), cfg, bits)` for tensors above `min_elems`.
pub fn cluster_weights(w: &Tensor, cfg: &SplitConfig) -> Clustering1D {
    match cfg.strategy {
        Strategy::MaskedSum => cluster_values(w.data(), cfg),
        Strategy::RowWise => {
            assert_eq!(w.ndim(), 2, "RowWise split requires a matrix");
            cluster_values(&row_absmax(w), cfg)
        }
    }
}

/// **Phase 2 of the fused hot path**: quantize `w` under a clustering
/// previously computed by [`cluster_weights`]. For MaskedSum this never
/// materializes FP planes: each value's quantized level is written
/// directly into its cluster's i8 plane (other planes get that cluster's
/// exact-zero level).
pub fn split_quantize_clustered(
    w: &Tensor,
    clustering: Clustering1D,
    cfg: &SplitConfig,
    bits: Bits,
) -> QuantizedSplitLayer {
    let k = clustering.k();
    match cfg.strategy {
        Strategy::MaskedSum => {
            if k <= 1 {
                return QuantizedSplitLayer {
                    planes: vec![quant::quantize_per_tensor(w, bits)],
                    clustering,
                    strategy: cfg.strategy,
                };
            }
            // Per-cluster quantization params from the cluster ranges
            // (identical to plane min/max: the plane's nonzeros span the
            // cluster range and `from_range` widens to 0 — the masked
            // value — itself).
            let ranges = per_cluster_ranges(w.data(), &clustering, k);
            let params: Vec<QuantParams> = ranges
                .iter()
                .map(|&(lo, hi)| QuantParams::from_range(bits, lo, hi))
                .collect();
            let zero_levels: Vec<i8> = params.iter().map(|p| p.quantize(0.0)).collect();
            let mut planes: Vec<Vec<i8>> = zero_levels
                .iter()
                .map(|&z| vec![z; w.len()])
                .collect();
            for (i, &v) in w.data().iter().enumerate() {
                let c = clustering.assign(v);
                planes[c][i] = params[c].quantize(v);
            }
            QuantizedSplitLayer {
                planes: planes
                    .into_iter()
                    .zip(&params)
                    .map(|(plane, &p)| QuantizedTensor {
                        plane: TensorI8::new(w.shape(), plane),
                        granularity: Granularity::PerTensor,
                        params: vec![p],
                    })
                    .collect(),
                clustering,
                strategy: cfg.strategy,
            }
        }
        Strategy::RowWise => {
            if k <= 1 {
                return quantize_split(&identity_split(w, cfg.strategy), bits);
            }
            // The row statistic is an O(n) rescan (the clustering itself
            // is the expensive part); planes partition rows exactly as
            // `split_tensor` does.
            let planes = scatter_rows(w, &row_absmax(w), &clustering);
            quantize_split(
                &SplitLayer {
                    planes,
                    clustering,
                    strategy: Strategy::RowWise,
                },
                bits,
            )
        }
    }
}

/// **Fused split + quantize** — the production hot path (the paper's
/// 2-minute preprocessing claim), now expressed as cluster phase +
/// quantize phase so the pipeline engine can run the phases per layer.
/// Numerically identical to `quantize_split(split_tensor(...))`.
pub fn split_quantize(w: &Tensor, cfg: &SplitConfig, bits: Bits) -> QuantizedSplitLayer {
    if w.len() < cfg.min_elems {
        return QuantizedSplitLayer {
            planes: vec![quant::quantize_per_tensor(w, bits)],
            clustering: identity_split(w, cfg.strategy).clustering,
            strategy: cfg.strategy,
        };
    }
    split_quantize_clustered(w, cluster_weights(w, cfg), cfg, bits)
}

/// Min/max of the values assigned to each cluster. Uses the solver's
/// tracked member extremes when available (no re-scan — §Perf opt #3);
/// falls back to a scan otherwise.
fn per_cluster_ranges(values: &[f32], clustering: &Clustering1D, k: usize) -> Vec<(f32, f32)> {
    if let Some(r) = &clustering.member_ranges {
        if r.len() == k {
            return r.clone();
        }
    }
    let mut lo = vec![f32::INFINITY; k];
    let mut hi = vec![f32::NEG_INFINITY; k];
    for &v in values {
        let c = clustering.assign(v);
        if v < lo[c] {
            lo[c] = v;
        }
        if v > hi[c] {
            hi[c] = v;
        }
    }
    (0..k)
        .map(|c| {
            if lo[c] > hi[c] {
                (0.0, 0.0) // empty cluster (cannot happen with exact DP)
            } else {
                (lo[c], hi[c])
            }
        })
        .collect()
}

/// One-call evaluation path: the effective (dequantized) weight of
/// SplitQuantV2 at `bits`. Compare against `quant::fake_quantize` for the
/// baseline arm of Table 1.
pub fn split_fake_quantize(w: &Tensor, cfg: &SplitConfig, bits: Bits) -> Tensor {
    split_quantize(w, cfg, bits).effective_weight()
}

/// Per-plane resolution report (Figure 1 / E6): scaling factors, steps,
/// and the end-to-end quantization MSE with and without splitting.
#[derive(Clone, Debug)]
pub struct ResolutionReport {
    pub bits: Bits,
    pub original_scale: f64,
    pub original_mse: f64,
    pub plane_scales: Vec<f64>,
    pub plane_sizes: Vec<f64>,
    pub split_mse: f64,
    /// original_mse / split_mse (≥ 1 when splitting helps).
    pub mse_gain: f64,
}

pub fn resolution_report(w: &Tensor, cfg: &SplitConfig, bits: Bits) -> ResolutionReport {
    let original = QuantParams::of_tensor(bits, w);
    let original_mse = quant::quant_mse(w, bits);
    let qsl = split_quantize(w, cfg, bits);
    let eff = qsl.effective_weight();
    let split_mse = crate::util::stats::mse(w.data(), eff.data());
    ResolutionReport {
        bits,
        original_scale: original.scale,
        original_mse,
        plane_scales: qsl.planes.iter().map(|p| p.params[0].scale).collect(),
        plane_sizes: qsl.clustering.sizes.clone(),
        split_mse,
        mse_gain: if split_mse > 0.0 {
            original_mse / split_mse
        } else {
            f64::INFINITY
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn heavy_tensor(seed: u64, rows: usize, cols: usize) -> Tensor {
        // LLM-like weights: mostly small values, a few big outliers.
        let mut r = Rng::new(seed);
        let mut data: Vec<f32> = (0..rows * cols)
            .map(|_| r.normal_f32(0.0, 0.05))
            .collect();
        let n_out = (data.len() / 100).max(2);
        for _ in 0..n_out {
            let i = r.below(data.len());
            data[i] = r.uniform_in(1.5, 3.0) * if r.uniform() < 0.5 { -1.0 } else { 1.0 };
        }
        Tensor::new(&[rows, cols], data)
    }

    #[test]
    fn planes_sum_to_original_bit_exact() {
        let w = heavy_tensor(1, 16, 32);
        let sl = split_tensor(&w, &SplitConfig::default());
        assert_eq!(sl.k(), 3);
        let rec = sl.reconstruct();
        assert_eq!(rec.data(), w.data(), "masked-sum must be bit-exact");
    }

    #[test]
    fn each_position_nonzero_in_exactly_one_plane() {
        let w = heavy_tensor(2, 8, 16);
        let sl = split_tensor(&w, &SplitConfig::default());
        for i in 0..w.len() {
            let nz = sl
                .planes
                .iter()
                .filter(|p| p.data()[i] != 0.0)
                .count();
            let expected = if w.data()[i] != 0.0 { 1 } else { 0 };
            assert_eq!(nz, expected, "position {i}");
        }
    }

    #[test]
    fn rowwise_planes_partition_rows() {
        let mut r = Rng::new(3);
        let mut data = Vec::new();
        for row in 0..12 {
            let s = if row % 4 == 0 { 2.0 } else { 0.05 };
            for _ in 0..8 {
                data.push(r.normal_f32(0.0, s));
            }
        }
        let w = Tensor::new(&[12, 8], data);
        let cfg = SplitConfig {
            strategy: Strategy::RowWise,
            k: 2,
            ..Default::default()
        };
        let sl = split_tensor(&w, &cfg);
        assert_eq!(sl.reconstruct().data(), w.data());
        // Every row lives wholly in one plane.
        for row in 0..12 {
            let owners = sl
                .planes
                .iter()
                .filter(|p| p.row(row).iter().any(|&v| v != 0.0))
                .count();
            assert!(owners <= 1, "row {row} split across planes");
        }
    }

    #[test]
    fn fused_equals_unfused() {
        let w = heavy_tensor(4, 24, 24);
        let cfg = SplitConfig::default();
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let fused = split_quantize(&w, &cfg, bits);
            let unfused = quantize_split(&split_tensor(&w, &cfg), bits);
            assert_eq!(fused.k(), unfused.k(), "{bits:?}");
            for (a, b) in fused.planes.iter().zip(&unfused.planes) {
                assert_eq!(a.params[0], b.params[0], "{bits:?} params");
                assert_eq!(a.plane.data(), b.plane.data(), "{bits:?} plane");
            }
        }
    }

    #[test]
    fn split_improves_int4_resolution_with_outliers() {
        let w = heavy_tensor(5, 32, 32);
        let rep = resolution_report(&w, &SplitConfig::default(), Bits::Int4);
        // Middle plane must have a much larger scaling factor than the
        // original layer (= the Figure 1 claim).
        let max_plane_scale = rep
            .plane_scales
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        assert!(
            max_plane_scale > rep.original_scale * 5.0,
            "plane scale {max_plane_scale} vs original {}",
            rep.original_scale
        );
        // And the end-to-end MSE gain is large.
        assert!(rep.mse_gain > 10.0, "mse gain {}", rep.mse_gain);
    }

    #[test]
    fn split_never_hurts_mse() {
        // Even on outlier-free Gaussians, narrower ranges can only help.
        let mut r = Rng::new(6);
        let w = Tensor::new(
            &[16, 16],
            (0..256).map(|_| r.normal_f32(0.0, 1.0)).collect(),
        );
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let rep = resolution_report(&w, &SplitConfig::default(), bits);
            assert!(
                rep.split_mse <= rep.original_mse * 1.0 + 1e-12,
                "{bits:?}: split {} > original {}",
                rep.split_mse,
                rep.original_mse
            );
        }
    }

    #[test]
    fn masked_zeros_do_not_leak_noise() {
        let w = heavy_tensor(7, 16, 16);
        let qsl = split_quantize(&w, &SplitConfig::default(), Bits::Int4);
        for (j, p) in qsl.planes.iter().enumerate() {
            let dq = p.dequantize();
            for i in 0..w.len() {
                let c = qsl.clustering.assign(w.data()[i]);
                if c != j {
                    assert_eq!(dq.data()[i], 0.0, "plane {j} leaked at {i}");
                }
            }
        }
    }

    #[test]
    fn tiny_tensor_skipped() {
        let w = Tensor::from_vec(vec![1.0, -1.0, 2.0]);
        let sl = split_tensor(&w, &SplitConfig::default());
        assert_eq!(sl.k(), 1);
        assert_eq!(sl.planes[0].data(), w.data());
    }

    #[test]
    fn constant_tensor_degenerates_gracefully() {
        let w = Tensor::full(&[16, 16], 0.7);
        let cfg = SplitConfig::default();
        let sl = split_tensor(&w, &cfg);
        assert_eq!(sl.k(), 1);
        let q = split_quantize(&w, &cfg, Bits::Int4);
        assert_eq!(q.k(), 1);
        assert!(q.effective_weight().allclose(&w, 0.05));
    }

    #[test]
    fn dynamic_k_uses_structure() {
        // Strong 3-blob structure → dynamic-k picks 3.
        let mut r = Rng::new(8);
        let mut data = Vec::new();
        for _ in 0..300 {
            data.push(r.normal_f32(-4.0, 0.02));
            data.push(r.normal_f32(0.0, 0.02));
            data.push(r.normal_f32(4.0, 0.02));
        }
        let w = Tensor::from_vec(data);
        let cfg = SplitConfig {
            dynamic_k: Some(DynamicK {
                k_max: 4,
                elbow: 0.25,
            }),
            ..Default::default()
        };
        let sl = split_tensor(&w, &cfg);
        assert_eq!(sl.k(), 3);
    }

    #[test]
    fn k2_config_produces_two_planes() {
        let w = heavy_tensor(9, 16, 16);
        let qsl = split_quantize(&w, &SplitConfig::with_k(2), Bits::Int4);
        assert_eq!(qsl.k(), 2);
        // k=2 still beats no split on outliers, but (typically) not k=3.
        let r2 = resolution_report(&w, &SplitConfig::with_k(2), Bits::Int4);
        let r3 = resolution_report(&w, &SplitConfig::with_k(3), Bits::Int4);
        assert!(r2.split_mse < r2.original_mse);
        assert!(r3.split_mse <= r2.split_mse * 1.5);
    }

    #[test]
    fn packed_size_is_k_times_baseline() {
        let w = heavy_tensor(10, 32, 32);
        let qsl = split_quantize(&w, &SplitConfig::default(), Bits::Int4);
        let baseline = quant::quantize_per_tensor(&w, Bits::Int4).packed_len();
        assert_eq!(qsl.packed_len(), 3 * baseline);
    }

    #[test]
    fn conv_kernel_tensors_split_positionally() {
        // The CV lineage of SplitQuant: 4-D conv weights [out, in, kh, kw]
        // split via the same positional masking (DESIGN.md §1).
        let mut r = Rng::new(21);
        let mut data: Vec<f32> = (0..16 * 8 * 3 * 3).map(|_| r.normal_f32(0.0, 0.05)).collect();
        data[10] = 2.0;
        data[700] = -1.8;
        let w = Tensor::new(&[16, 8, 3, 3], data);
        let sl = split_tensor(&w, &SplitConfig::default());
        assert_eq!(sl.k(), 3);
        assert_eq!(sl.planes[0].shape(), &[16, 8, 3, 3]);
        assert_eq!(sl.reconstruct().data(), w.data());
        let q = split_quantize(&w, &SplitConfig::default(), Bits::Int4);
        let rep_mse = crate::util::stats::mse(w.data(), q.effective_weight().data());
        let base_mse = quant::quant_mse(&w, Bits::Int4);
        assert!(rep_mse < base_mse * 0.25, "conv split {rep_mse} vs base {base_mse}");
    }

    #[test]
    fn phased_cluster_then_quantize_equals_fused() {
        // The pipeline engine runs the two phases separately; they must
        // compose to exactly the fused hot path for both strategies.
        let w = heavy_tensor(12, 24, 24);
        for strategy in [Strategy::MaskedSum, Strategy::RowWise] {
            let cfg = SplitConfig {
                strategy,
                ..Default::default()
            };
            let fused = split_quantize(&w, &cfg, Bits::Int4);
            let clustering = cluster_weights(&w, &cfg);
            let phased = split_quantize_clustered(&w, clustering, &cfg, Bits::Int4);
            assert_eq!(fused.k(), phased.k(), "{strategy:?}");
            for (a, b) in fused.planes.iter().zip(&phased.planes) {
                assert_eq!(a.plane.data(), b.plane.data(), "{strategy:?}");
                assert_eq!(a.params, b.params, "{strategy:?}");
            }
        }
    }

    #[test]
    fn member_ranges_match_scanned_ranges() {
        // §Perf opt #3 exactness contract: solver-tracked member ranges
        // equal a full re-scan for both the exact-DP and histogram paths.
        for (seed, n) in [(31u64, 5_000usize), (32, 300_000)] {
            let mut r = Rng::new(seed);
            let vals: Vec<f32> = (0..n).map(|_| (r.heavy_tailed(3.0) * 0.05) as f32).collect();
            let c = crate::kmeans::kmeans_auto(&vals, 3);
            let tracked = c.member_ranges.clone().expect("solver must track ranges");
            let mut lo = vec![f32::INFINITY; c.k()];
            let mut hi = vec![f32::NEG_INFINITY; c.k()];
            for &v in &vals {
                let cl = c.assign(v);
                lo[cl] = lo[cl].min(v);
                hi[cl] = hi[cl].max(v);
            }
            for j in 0..c.k() {
                assert_eq!(tracked[j].0, lo[j], "n={n} cluster {j} min");
                assert_eq!(tracked[j].1, hi[j], "n={n} cluster {j} max");
            }
        }
    }

    #[test]
    fn effective_weight_error_bounded_by_cluster_step() {
        let w = heavy_tensor(11, 16, 16);
        let qsl = split_quantize(&w, &SplitConfig::default(), Bits::Int4);
        let eff = qsl.effective_weight();
        for i in 0..w.len() {
            let c = qsl.clustering.assign(w.data()[i]);
            let step = qsl.planes[c].params[0].step();
            let err = ((w.data()[i] - eff.data()[i]) as f64).abs();
            assert!(
                err <= 0.5 * step + 1e-6,
                "i={i}: err {err} > half-step {}",
                0.5 * step
            );
        }
    }
}
