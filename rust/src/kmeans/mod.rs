//! K-means clustering — the analytical core of SplitQuantV2.
//!
//! The paper clusters the scalar weight values of each layer into k=3
//! (lower / middle / upper) groups. In one dimension, optimal k-means
//! clusters are *contiguous intervals* of the sorted values, so the
//! problem is solved **exactly** by dynamic programming — no Lloyd
//! iteration, no initialization sensitivity. Three implementations:
//!
//! * [`dp1d::kmeans_exact`] — exact O(k·n log n) divide-and-conquer DP on
//!   sorted (optionally weighted) values. Ground truth; used directly for
//!   layers up to ~262k values.
//! * [`hist::kmeans_hist`] — histogram-compressed DP: values are bucketed
//!   into a fixed number of bins and the *weighted* exact DP runs on the
//!   bins. This is the production path for multi-million-parameter layers
//!   (the 1B-in-2-minutes hot loop); resolution is bounded by the bin
//!   width, which at 4096 bins is far below quantization step size.
//! * [`lloyd::kmeans_lloyd`] — classic Lloyd's with k-means++ seeding for
//!   n-dimensional data; used by the activation-splitting extension (§5
//!   of the paper) where calibration activations are clustered.
//!
//! All three return a [`Clustering1D`] (or [`lloyd::ClusteringND`]) whose
//! `boundaries` let callers assign values in O(log k).

pub mod dp1d;
pub mod hist;
pub mod lloyd;

pub use dp1d::kmeans_exact;
pub use hist::kmeans_hist;
pub use lloyd::kmeans_lloyd;

/// Result of a 1-D clustering: `centroids` ascending, `boundaries[i]` is
/// the threshold between cluster i and i+1 (value `x` belongs to cluster
/// `i` iff `boundaries[i-1] < x <= boundaries[i]` with sentinels ±inf).
#[derive(Clone, Debug)]
pub struct Clustering1D {
    pub centroids: Vec<f64>,
    pub boundaries: Vec<f64>,
    /// Sum of squared distances to assigned centroids.
    pub inertia: f64,
    /// Number of points (total weight) per cluster.
    pub sizes: Vec<f64>,
    /// Exact (min, max) of the *member values* of each cluster, when the
    /// solver can provide it for free (exact DP: cluster edges of the
    /// sorted input; histogram DP: tracked per-bin extremes). Lets the
    /// split hot path skip a full re-scan of the weights (§Perf opt #3).
    pub member_ranges: Option<Vec<(f32, f32)>>,
}

impl Clustering1D {
    /// Number of clusters actually produced (≤ requested k when there are
    /// fewer distinct values).
    pub fn k(&self) -> usize {
        self.centroids.len()
    }

    /// Cluster index for a value (O(k); k is 2..4 in practice so this
    /// compiles to a couple of compares).
    #[inline]
    pub fn assign(&self, x: f32) -> usize {
        let x = x as f64;
        let mut i = 0;
        while i < self.boundaries.len() && x > self.boundaries[i] {
            i += 1;
        }
        i
    }

    /// Midpoint boundaries derived from consecutive centroids. (The DP
    /// returns exact interval edges; Lloyd-style midpoints are equivalent
    /// for assignment of *new* points.)
    pub fn from_centroids(mut centroids: Vec<f64>, inertia: f64, sizes: Vec<f64>) -> Self {
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let boundaries = centroids
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect();
        Clustering1D {
            centroids,
            boundaries,
            inertia,
            sizes,
            member_ranges: None,
        }
    }

    /// Value range (min..max gap) covered by each cluster given the data
    /// extremes — used to report the per-split quantization ranges.
    pub fn cluster_ranges(&self, data_min: f64, data_max: f64) -> Vec<(f64, f64)> {
        let k = self.k();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let lo = if i == 0 { data_min } else { self.boundaries[i - 1] };
            let hi = if i == k - 1 { data_max } else { self.boundaries[i] };
            out.push((lo, hi));
        }
        out
    }
}

/// Strategy selector used by the split pipeline: exact DP below the
/// threshold, histogram DP above it.
pub const EXACT_DP_MAX_N: usize = 1 << 18;

/// Cluster `values` into `k` groups using the best method for the size.
pub fn kmeans_auto(values: &[f32], k: usize) -> Clustering1D {
    if values.len() <= EXACT_DP_MAX_N {
        dp1d::kmeans_exact(values, k)
    } else {
        hist::kmeans_hist(values, k, hist::DEFAULT_BINS)
    }
}

/// Inertia of assigning `values` to fixed `clustering` (for tests and for
/// dynamic-k elbow scoring on subsamples).
pub fn inertia_of(values: &[f32], c: &Clustering1D) -> f64 {
    values
        .iter()
        .map(|&v| {
            let d = v as f64 - c.centroids[c.assign(v)];
            d * d
        })
        .sum()
}

/// Dynamic-k selection (§5 future work): largest k in `1..=k_max` such
/// that every step up to k improved inertia by at least `elbow`
/// (relative). Returns (k, clusterings tried).
pub fn choose_k(values: &[f32], k_max: usize, elbow: f64) -> (usize, Vec<Clustering1D>) {
    assert!(k_max >= 1);
    let mut tried = Vec::new();
    let mut prev_inertia = f64::INFINITY;
    let mut chosen = 1;
    for k in 1..=k_max {
        let c = kmeans_auto(values, k);
        let inertia = c.inertia;
        if k > 1 {
            let improvement = if prev_inertia > 0.0 {
                1.0 - inertia / prev_inertia
            } else {
                0.0
            };
            if chosen == k - 1 && improvement >= elbow {
                chosen = k;
            }
        }
        prev_inertia = inertia;
        tried.push(c);
    }
    (chosen, tried)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_respects_boundaries() {
        let c = Clustering1D {
            centroids: vec![-5.0, 0.0, 5.0],
            boundaries: vec![-2.5, 2.5],
            inertia: 0.0,
            sizes: vec![1.0, 1.0, 1.0],
            member_ranges: None,
        };
        assert_eq!(c.assign(-10.0), 0);
        assert_eq!(c.assign(-2.5), 0); // boundary inclusive on the left
        assert_eq!(c.assign(0.0), 1);
        assert_eq!(c.assign(2.6), 2);
    }

    #[test]
    fn cluster_ranges_partition_data_range() {
        let c = Clustering1D {
            centroids: vec![-5.0, 0.0, 5.0],
            boundaries: vec![-2.5, 2.5],
            inertia: 0.0,
            sizes: vec![1.0, 1.0, 1.0],
            member_ranges: None,
        };
        let r = c.cluster_ranges(-9.0, 9.0);
        assert_eq!(r, vec![(-9.0, -2.5), (-2.5, 2.5), (2.5, 9.0)]);
    }

    #[test]
    fn auto_dispatches_consistently() {
        // Small vector: exact and hist agree on well-separated clusters.
        let mut vals = Vec::new();
        for i in 0..50 {
            vals.push(-10.0 + (i as f32) * 0.01);
            vals.push(10.0 + (i as f32) * 0.01);
        }
        let exact = kmeans_exact(&vals, 2);
        let auto = kmeans_auto(&vals, 2);
        assert_eq!(exact.k(), 2);
        for (a, b) in exact.centroids.iter().zip(&auto.centroids) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn choose_k_prefers_structure() {
        // Three well-separated blobs: inertia drops hugely up to k=3 and
        // barely after, so the elbow picks 3.
        let mut vals = Vec::new();
        for i in 0..200 {
            let j = (i % 17) as f32 * 0.001;
            vals.push(-8.0 + j);
            vals.push(0.0 + j);
            vals.push(8.0 + j);
        }
        let (k, tried) = choose_k(&vals, 4, 0.25);
        assert_eq!(k, 3);
        assert_eq!(tried.len(), 4);
        // Inertia is monotone nonincreasing in k.
        for w in tried.windows(2) {
            assert!(w[1].inertia <= w[0].inertia + 1e-9);
        }
    }

    #[test]
    fn choose_k_on_uniform_prefers_small() {
        // A single tight blob with a near-impossible elbow: stays at 1.
        let vals: Vec<f32> = (0..300).map(|i| 5.0 + (i as f32) * 1e-4).collect();
        let (k, _) = choose_k(&vals, 4, 0.9999);
        assert_eq!(k, 1);
    }
}
