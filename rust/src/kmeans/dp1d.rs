//! Exact 1-D k-means by dynamic programming.
//!
//! Optimal 1-D k-means clusters are contiguous intervals of the sorted
//! input (a classical result; see Wang & Song, "Ckmeans.1d.dp"). With
//! prefix sums the within-cluster cost of any interval is O(1), and the
//! DP layer recurrence
//!
//!   D_k(i) = min_{m ≤ i} D_{k-1}(m) + cost(m, i)
//!
//! has monotone optimal split points, so each layer is computed with
//! divide-and-conquer in O(n log n) — O(k·n log n) total, exact.
//!
//! Supports weighted points (the histogram path feeds bin centers with
//! counts); the unweighted API wraps weights of 1.

use super::Clustering1D;

/// Weighted sorted-input DP. `xs` must be ascending; `ws[i] > 0`.
pub fn kmeans_weighted_sorted(xs: &[f64], ws: &[f64], k: usize) -> Clustering1D {
    assert_eq!(xs.len(), ws.len());
    assert!(k >= 1, "k must be >= 1");
    let n = xs.len();
    assert!(n > 0, "kmeans on empty input");
    debug_assert!(xs.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");

    // Effective k: cannot exceed the number of distinct values.
    let distinct = {
        let mut d = 1;
        for w in xs.windows(2) {
            if w[1] > w[0] {
                d += 1;
            }
        }
        d
    };
    let k = k.min(distinct);

    // Prefix sums: weight, weight*x, weight*x^2.
    let mut pw = vec![0.0f64; n + 1];
    let mut ps = vec![0.0f64; n + 1];
    let mut pss = vec![0.0f64; n + 1];
    for i in 0..n {
        pw[i + 1] = pw[i] + ws[i];
        ps[i + 1] = ps[i] + ws[i] * xs[i];
        pss[i + 1] = pss[i] + ws[i] * xs[i] * xs[i];
    }
    // Within-cluster sum of squares for half-open interval [a, b).
    let cost = |a: usize, b: usize| -> f64 {
        let w = pw[b] - pw[a];
        if w <= 0.0 {
            return 0.0;
        }
        let s = ps[b] - ps[a];
        let ss = pss[b] - pss[a];
        (ss - s * s / w).max(0.0) // clamp tiny negative fp residue
    };

    // D[i] = best cost of clustering the first i points into the current
    // number of layers; splits[layer][i] = argmin split for backtracking.
    let mut prev = vec![0.0f64; n + 1];
    for i in 1..=n {
        prev[i] = cost(0, i);
    }
    let mut splits: Vec<Vec<usize>> = Vec::with_capacity(k);
    splits.push(vec![0; n + 1]); // layer 1: everything in one cluster

    for _layer in 2..=k {
        let mut cur = vec![f64::INFINITY; n + 1];
        let mut arg = vec![0usize; n + 1];
        cur[0] = 0.0;
        // Divide and conquer over i in [layer, n], opt split in [layer-1, i].
        dnc(&mut cur, &mut arg, &prev, &cost, 1, n, 1, n);
        prev = cur;
        splits.push(arg);
    }

    // Backtrack boundaries (indices where clusters split).
    let mut edges = vec![n]; // exclusive end of last cluster
    let mut i = n;
    for layer in (1..k).rev() {
        let m = splits[layer][i];
        edges.push(m);
        i = m;
    }
    edges.push(0);
    edges.reverse(); // [0, m1, m2, ..., n]

    let mut centroids = Vec::with_capacity(k);
    let mut sizes = Vec::with_capacity(k);
    let mut boundaries = Vec::with_capacity(k.saturating_sub(1));
    let mut member_ranges = Vec::with_capacity(k);
    for c in 0..k {
        let (a, b) = (edges[c], edges[c + 1]);
        let w = pw[b] - pw[a];
        centroids.push(if w > 0.0 { (ps[b] - ps[a]) / w } else { xs[a] });
        sizes.push(w);
        // Clusters are contiguous intervals of the sorted input, so the
        // member extremes are the interval edges (exact, no extra pass).
        member_ranges.push((xs[a] as f32, xs[b - 1] as f32));
        if c + 1 < k {
            // Exact decision boundary between adjacent intervals: any value
            // in (xs[b-1], xs[b]) separates them; use the midpoint.
            boundaries.push(0.5 * (xs[b - 1] + xs[b]));
        }
    }

    Clustering1D {
        centroids,
        boundaries,
        inertia: prev[n],
        sizes,
        member_ranges: Some(member_ranges),
    }
}

/// Divide-and-conquer DP layer fill: for i in [ilo, ihi], cur[i] =
/// min over m in [mlo, mhi∩(0..i]] of prev[m] + cost(m, i); exploits
/// monotonicity of the argmin.
fn dnc(
    cur: &mut [f64],
    arg: &mut [usize],
    prev: &[f64],
    cost: &impl Fn(usize, usize) -> f64,
    ilo: usize,
    ihi: usize,
    mlo: usize,
    mhi: usize,
) {
    if ilo > ihi {
        return;
    }
    let i = (ilo + ihi) / 2;
    let mut best = f64::INFINITY;
    let mut best_m = mlo;
    let hi = mhi.min(i);
    for m in mlo..=hi {
        let v = prev[m] + cost(m, i);
        if v < best {
            best = v;
            best_m = m;
        }
    }
    cur[i] = best;
    arg[i] = best_m;
    if ilo < i {
        dnc(cur, arg, prev, cost, ilo, i - 1, mlo, best_m);
    }
    if i < ihi {
        dnc(cur, arg, prev, cost, i + 1, ihi, best_m, mhi);
    }
}

/// Exact k-means of unsorted f32 values (sorts a copy).
pub fn kmeans_exact(values: &[f32], k: usize) -> Clustering1D {
    let mut xs: Vec<f64> = values.iter().map(|&v| v as f64).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in k-means input"));
    let ws = vec![1.0f64; xs.len()];
    kmeans_weighted_sorted(&xs, &ws, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::inertia_of;
    use crate::util::rng::Rng;

    /// Brute-force optimal clustering by trying all contiguous partitions.
    fn brute_force(values: &[f32], k: usize) -> f64 {
        let mut xs: Vec<f64> = values.iter().map(|&v| v as f64).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let cost = |a: usize, b: usize| -> f64 {
            let seg = &xs[a..b];
            let m = seg.iter().sum::<f64>() / seg.len() as f64;
            seg.iter().map(|x| (x - m) * (x - m)).sum()
        };
        // Enumerate split points.
        fn rec(cost: &dyn Fn(usize, usize) -> f64, start: usize, n: usize, k: usize) -> f64 {
            if k == 1 {
                return cost(start, n);
            }
            let mut best = f64::INFINITY;
            for m in start + 1..=n - (k - 1) {
                let c = cost(start, m) + rec(cost, m, n, k - 1);
                if c < best {
                    best = c;
                }
            }
            best
        }
        rec(&cost, 0, n, k.min(n))
    }

    #[test]
    fn trivial_cases() {
        let c = kmeans_exact(&[5.0], 3);
        assert_eq!(c.k(), 1);
        assert_eq!(c.centroids, vec![5.0]);
        assert_eq!(c.inertia, 0.0);

        let c = kmeans_exact(&[1.0, 1.0, 1.0], 3);
        assert_eq!(c.k(), 1, "identical values collapse to one cluster");
    }

    #[test]
    fn separates_obvious_blobs() {
        let vals = [-10.0, -9.8, -10.2, 0.1, -0.1, 0.0, 9.9, 10.0, 10.1f32];
        let c = kmeans_exact(&vals, 3);
        assert_eq!(c.k(), 3);
        assert!((c.centroids[0] + 10.0).abs() < 0.1);
        assert!(c.centroids[1].abs() < 0.1);
        assert!((c.centroids[2] - 10.0).abs() < 0.1);
        // Every point lands in its blob.
        for &v in &vals {
            let cl = c.assign(v);
            let expected = if v < -5.0 {
                0
            } else if v < 5.0 {
                1
            } else {
                2
            };
            assert_eq!(cl, expected, "value {v}");
        }
    }

    #[test]
    fn matches_brute_force_on_random_inputs() {
        let mut r = Rng::new(123);
        for trial in 0..30 {
            let n = 4 + r.below(12);
            let k = 1 + r.below(4.min(n));
            let vals: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 3.0)).collect();
            let dp = kmeans_weighted_sorted(
                &{
                    let mut s: Vec<f64> = vals.iter().map(|&v| v as f64).collect();
                    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    s
                },
                &vec![1.0; n],
                k,
            );
            let bf = brute_force(&vals, k);
            assert!(
                (dp.inertia - bf).abs() < 1e-6 * (1.0 + bf),
                "trial {trial}: dp={} bf={} (n={n}, k={k})",
                dp.inertia,
                bf
            );
        }
    }

    #[test]
    fn inertia_matches_assignment_inertia() {
        let mut r = Rng::new(7);
        let vals: Vec<f32> = (0..500).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let c = kmeans_exact(&vals, 3);
        let recomputed = inertia_of(&vals, &c);
        assert!(
            (c.inertia - recomputed).abs() < 1e-6 * (1.0 + c.inertia),
            "dp inertia {} vs recomputed {}",
            c.inertia,
            recomputed
        );
    }

    #[test]
    fn weights_scale_like_duplication() {
        // Weighted points == duplicated points.
        let xs = [1.0, 2.0, 10.0];
        let ws = [3.0, 1.0, 2.0];
        let dup: Vec<f32> = vec![1.0, 1.0, 1.0, 2.0, 10.0, 10.0];
        let a = kmeans_weighted_sorted(&xs, &ws, 2);
        let b = kmeans_exact(&dup, 2);
        assert!((a.inertia - b.inertia).abs() < 1e-9);
        for (x, y) in a.centroids.iter().zip(&b.centroids) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn monotone_inertia_in_k() {
        let mut r = Rng::new(99);
        let vals: Vec<f32> = (0..300).map(|_| r.heavy_tailed(3.0) as f32).collect();
        let mut last = f64::INFINITY;
        for k in 1..=5 {
            let c = kmeans_exact(&vals, k);
            assert!(c.inertia <= last + 1e-9, "k={k}");
            last = c.inertia;
        }
    }

    #[test]
    fn centroids_strictly_ascending() {
        let mut r = Rng::new(5);
        let vals: Vec<f32> = (0..1000).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let c = kmeans_exact(&vals, 4);
        for w in c.centroids.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(c.boundaries.len(), c.k() - 1);
    }

    #[test]
    fn outliers_get_isolated() {
        // The paper's motivating case: a dense middle + a few extreme
        // outliers. k=3 must put outliers in the edge clusters.
        let mut r = Rng::new(31);
        let mut vals: Vec<f32> = (0..2000).map(|_| r.normal_f32(0.0, 0.05)).collect();
        vals.push(12.0);
        vals.push(13.0);
        vals.push(-11.0);
        let c = kmeans_exact(&vals, 3);
        assert_eq!(c.k(), 3);
        assert_eq!(c.assign(-11.0), 0);
        assert_eq!(c.assign(12.5), 2);
        assert_eq!(c.assign(0.0), 1);
        // Middle cluster holds the overwhelming majority.
        assert!(c.sizes[1] > 1990.0);
        // The members of the middle cluster span a tiny range versus the
        // full data range (this is the resolution win the paper is about).
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in &vals {
            if c.assign(v) == 1 {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        let mid_width = (hi - lo) as f64;
        assert!(mid_width < 24.0 * 0.05, "mid width {mid_width}");
    }
}
