//! Lloyd's algorithm with k-means++ seeding, for n-dimensional points.
//!
//! Used by the activation-splitting extension (§5 of the paper): simulated
//! activation vectors from a calibration batch are clustered to derive the
//! masking partition. Also serves as an independent reference for the 1-D
//! DP solver in tests (Lloyd can only do as well or worse — the DP is
//! globally optimal).

use crate::util::rng::Rng;

/// Result of an n-D clustering.
#[derive(Clone, Debug)]
pub struct ClusteringND {
    /// k × dim centroid matrix, row-major.
    pub centroids: Vec<f64>,
    pub dim: usize,
    pub inertia: f64,
    pub sizes: Vec<usize>,
    pub iterations: usize,
}

impl ClusteringND {
    pub fn k(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.centroids.len() / self.dim
        }
    }

    /// Nearest-centroid assignment.
    pub fn assign(&self, point: &[f64]) -> usize {
        assert_eq!(point.len(), self.dim);
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for c in 0..self.k() {
            let d = dist2(&self.centroids[c * self.dim..(c + 1) * self.dim], point);
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        best
    }
}

#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y) * (x - y))
        .sum()
}

/// Lloyd's k-means. `points` is n × dim row-major. Deterministic given
/// the seed. Converges when assignments stop changing or `max_iters` hit.
pub fn kmeans_lloyd(
    points: &[f64],
    dim: usize,
    k: usize,
    max_iters: usize,
    seed: u64,
) -> ClusteringND {
    assert!(dim > 0);
    assert_eq!(points.len() % dim, 0);
    let n = points.len() / dim;
    assert!(n > 0, "kmeans on empty input");
    let k = k.min(n).max(1);
    let mut rng = Rng::new(seed);

    // k-means++ seeding.
    let mut centroids = Vec::with_capacity(k * dim);
    let first = rng.below(n);
    centroids.extend_from_slice(&points[first * dim..(first + 1) * dim]);
    let mut d2: Vec<f64> = (0..n)
        .map(|i| dist2(&points[i * dim..(i + 1) * dim], &centroids[0..dim]))
        .collect();
    for _ in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.uniform() * total;
            let mut idx = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                if target < d {
                    idx = i;
                    break;
                }
                target -= d;
            }
            idx
        };
        let new_c = &points[pick * dim..(pick + 1) * dim];
        centroids.extend_from_slice(new_c);
        for i in 0..n {
            let d = dist2(&points[i * dim..(i + 1) * dim], new_c);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    // Lloyd iterations.
    let mut assign = vec![usize::MAX; n];
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        let mut changed = false;
        for i in 0..n {
            let p = &points[i * dim..(i + 1) * dim];
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = dist2(&centroids[c * dim..(c + 1) * dim], p);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        if !changed {
            break;
        }
        // Recompute centroids; empty clusters re-seeded at the farthest point.
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for d in 0..dim {
                sums[c * dim + d] += points[i * dim + d];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed at the point farthest from its centroid.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = dist2(
                            &points[a * dim..(a + 1) * dim],
                            &centroids[assign[a] * dim..(assign[a] + 1) * dim],
                        );
                        let db = dist2(
                            &points[b * dim..(b + 1) * dim],
                            &centroids[assign[b] * dim..(assign[b] + 1) * dim],
                        );
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&points[far * dim..(far + 1) * dim]);
            } else {
                for d in 0..dim {
                    centroids[c * dim + d] = sums[c * dim + d] / counts[c] as f64;
                }
            }
        }
    }

    // Final stats.
    let mut inertia = 0.0;
    let mut sizes = vec![0usize; k];
    for i in 0..n {
        let p = &points[i * dim..(i + 1) * dim];
        let c = assign[i];
        sizes[c] += 1;
        inertia += dist2(&centroids[c * dim..(c + 1) * dim], p);
    }

    ClusteringND {
        centroids,
        dim,
        inertia,
        sizes,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::dp1d::kmeans_exact;
    use crate::util::rng::Rng;

    #[test]
    fn clusters_separated_2d_blobs() {
        let mut r = Rng::new(1);
        let mut pts = Vec::new();
        let blobs = [(-5.0, -5.0), (5.0, 5.0), (5.0, -5.0)];
        for &(cx, cy) in &blobs {
            for _ in 0..50 {
                pts.push(cx + r.normal() * 0.2);
                pts.push(cy + r.normal() * 0.2);
            }
        }
        let c = kmeans_lloyd(&pts, 2, 3, 100, 7);
        assert_eq!(c.k(), 3);
        assert_eq!(c.sizes.iter().sum::<usize>(), 150);
        // Every blob center has a nearby centroid.
        for &(cx, cy) in &blobs {
            let found = (0..3).any(|i| {
                dist2(&c.centroids[i * 2..i * 2 + 2], &[cx, cy]) < 0.5
            });
            assert!(found, "no centroid near ({cx},{cy})");
        }
    }

    #[test]
    fn lloyd_never_beats_exact_dp_in_1d() {
        let mut r = Rng::new(2);
        for trial in 0..10 {
            let vals: Vec<f32> = (0..200).map(|_| r.normal_f32(0.0, 2.0)).collect();
            let pts: Vec<f64> = vals.iter().map(|&v| v as f64).collect();
            let dp = kmeans_exact(&vals, 3);
            let ll = kmeans_lloyd(&pts, 1, 3, 200, trial as u64);
            assert!(
                ll.inertia >= dp.inertia - 1e-6,
                "trial {trial}: lloyd {} < dp {}",
                ll.inertia,
                dp.inertia
            );
            // And with a good seed it should usually be close.
            assert!(ll.inertia <= dp.inertia * 2.0 + 1e-9);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let pts: Vec<f64> = (0..60).map(|i| (i % 7) as f64).collect();
        let a = kmeans_lloyd(&pts, 2, 3, 50, 9);
        let b = kmeans_lloyd(&pts, 2, 3, 50, 9);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.sizes, b.sizes);
    }

    #[test]
    fn k_clamped_to_n() {
        let pts = [1.0, 2.0];
        let c = kmeans_lloyd(&pts, 1, 5, 10, 0);
        assert!(c.k() <= 2);
        assert!(c.inertia < 1e-12);
    }

    #[test]
    fn assign_matches_training_partition() {
        let mut r = Rng::new(4);
        let mut pts = Vec::new();
        for _ in 0..40 {
            pts.push(r.normal() - 6.0);
        }
        for _ in 0..40 {
            pts.push(r.normal() + 6.0);
        }
        let c = kmeans_lloyd(&pts, 1, 2, 100, 5);
        let lo_c = c.assign(&[-6.0]);
        let hi_c = c.assign(&[6.0]);
        assert_ne!(lo_c, hi_c);
        assert_eq!(c.assign(&[-8.0]), lo_c);
        assert_eq!(c.assign(&[7.0]), hi_c);
    }
}
