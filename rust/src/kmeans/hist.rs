//! Histogram-compressed 1-D k-means — the production path for layers with
//! millions of weights.
//!
//! Weight values are bucketed into `bins` equal-width bins over
//! [min, max]; each non-empty bin contributes one weighted point (its
//! *mean*, not its center, so first moments are exact) to the exact DP of
//! [`super::dp1d`]. Complexity: one O(n) pass + O(k·B log B) DP with
//! B = bins. Error versus exact k-means is bounded by the bin width,
//! which at the default 4096 bins is orders of magnitude below the INT4
//! quantization step the clusters feed into.

use super::dp1d::kmeans_weighted_sorted;
use super::Clustering1D;

pub const DEFAULT_BINS: usize = 4096;

/// Histogram k-means of raw values.
pub fn kmeans_hist(values: &[f32], k: usize, bins: usize) -> Clustering1D {
    assert!(!values.is_empty(), "kmeans on empty input");
    assert!(bins >= 2);
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in values {
        let v = v as f64;
        if v < lo {
            lo = v;
        }
        if v > hi {
            hi = v;
        }
    }
    if lo == hi {
        // Constant input: single cluster.
        return Clustering1D {
            centroids: vec![lo],
            boundaries: vec![],
            inertia: 0.0,
            sizes: vec![values.len() as f64],
            member_ranges: Some(vec![(lo as f32, hi as f32)]),
        };
    }

    let inv_width = bins as f64 / (hi - lo);
    let mut count = vec![0.0f64; bins];
    let mut sum = vec![0.0f64; bins];
    let mut sumsq = vec![0.0f64; bins];
    // Per-bin member extremes (f32): lets the split hot path derive exact
    // per-cluster quantization ranges without re-scanning the weights.
    let mut bmin = vec![f32::INFINITY; bins];
    let mut bmax = vec![f32::NEG_INFINITY; bins];
    for &vf in values {
        let v = vf as f64;
        let b = (((v - lo) * inv_width) as usize).min(bins - 1);
        count[b] += 1.0;
        sum[b] += v;
        sumsq[b] += v * v;
        if vf < bmin[b] {
            bmin[b] = vf;
        }
        if vf > bmax[b] {
            bmax[b] = vf;
        }
    }

    // Non-empty bins → weighted points at bin means (ascending because
    // bins are ordered and means lie inside their bins).
    let mut xs = Vec::with_capacity(bins);
    let mut ws = Vec::with_capacity(bins);
    let mut pmin = Vec::with_capacity(bins); // per-point member extremes
    let mut pmax = Vec::with_capacity(bins);
    let mut resid = 0.0f64; // within-bin variance, an exact inertia floor
    for b in 0..bins {
        if count[b] > 0.0 {
            let m = sum[b] / count[b];
            xs.push(m);
            ws.push(count[b]);
            pmin.push(bmin[b]);
            pmax.push(bmax[b]);
            resid += (sumsq[b] - sum[b] * m).max(0.0);
        }
    }

    let mut c = kmeans_weighted_sorted(&xs, &ws, k);
    // The DP's inertia is between bin means; add the within-bin residual
    // so the reported inertia approximates the true value-level inertia.
    c.inertia += resid;

    // Rewrite boundaries + member ranges at *value* granularity: the DP
    // clusters whole bins, so the separator between clusters c and c+1 is
    // any value between the last member of c and the first member of c+1
    // — use the midpoint of the tracked extremes so `assign(v)` agrees
    // exactly with bin membership for every observed value, and the
    // member ranges are the exact per-cluster min/max (§Perf opt #3).
    let kk = c.k();
    if kk >= 1 {
        // Recover the bin partition from the DP boundaries (bin means are
        // the DP points, correctly separated by its midpoint boundaries).
        let mut ranges = vec![(f32::INFINITY, f32::NEG_INFINITY); kk];
        let mut cur = 0usize;
        let mut last_max: Vec<f32> = vec![f32::NEG_INFINITY; kk];
        let mut first_min: Vec<f32> = vec![f32::INFINITY; kk];
        for (i, &x) in xs.iter().enumerate() {
            while cur < kk - 1 && x > c.boundaries[cur] {
                cur += 1;
            }
            let r = &mut ranges[cur];
            if pmin[i] < r.0 {
                r.0 = pmin[i];
            }
            if pmax[i] > r.1 {
                r.1 = pmax[i];
            }
            if pmin[i] < first_min[cur] {
                first_min[cur] = pmin[i];
            }
            if pmax[i] > last_max[cur] {
                last_max[cur] = pmax[i];
            }
        }
        for j in 0..kk - 1 {
            c.boundaries[j] = 0.5 * (last_max[j] as f64 + first_min[j + 1] as f64);
        }
        c.member_ranges = Some(ranges);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::{dp1d::kmeans_exact, inertia_of};
    use crate::util::rng::Rng;

    #[test]
    fn constant_input() {
        let c = kmeans_hist(&[2.5; 100], 3, 64);
        assert_eq!(c.k(), 1);
        assert_eq!(c.centroids, vec![2.5]);
    }

    #[test]
    fn close_to_exact_on_gaussian() {
        let mut r = Rng::new(42);
        let vals: Vec<f32> = (0..20_000).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let exact = kmeans_exact(&vals, 3);
        let hist = kmeans_hist(&vals, 3, DEFAULT_BINS);
        assert_eq!(hist.k(), 3);
        for (a, b) in exact.centroids.iter().zip(&hist.centroids) {
            assert!((a - b).abs() < 0.02, "centroid {a} vs {b}");
        }
        // Inertia within 1% of exact.
        assert!(
            (hist.inertia - exact.inertia).abs() < 0.01 * exact.inertia,
            "exact={} hist={}",
            exact.inertia,
            hist.inertia
        );
    }

    #[test]
    fn assignment_quality_on_heavy_tails() {
        // LLM-like weight distribution (heavy tails): the hist clustering
        // must yield near-exact assignment inertia.
        let mut r = Rng::new(7);
        let vals: Vec<f32> = (0..50_000).map(|_| (r.heavy_tailed(4.0) * 0.02) as f32).collect();
        let exact = kmeans_exact(&vals, 3);
        let hist = kmeans_hist(&vals, 3, DEFAULT_BINS);
        let i_exact = inertia_of(&vals, &exact);
        let i_hist = inertia_of(&vals, &hist);
        assert!(
            i_hist <= i_exact * 1.05 + 1e-12,
            "hist assignment inertia {} vs exact {}",
            i_hist,
            i_exact
        );
    }

    #[test]
    fn outlier_isolation_survives_binning() {
        let mut r = Rng::new(3);
        let mut vals: Vec<f32> = (0..100_000).map(|_| r.normal_f32(0.0, 0.02)).collect();
        vals.push(8.0);
        vals.push(-7.5);
        let c = kmeans_hist(&vals, 3, DEFAULT_BINS);
        assert_eq!(c.assign(8.0), 2);
        assert_eq!(c.assign(-7.5), 0);
        assert_eq!(c.assign(0.0), 1);
        assert!(c.sizes[1] > 99_000.0);
    }

    #[test]
    fn more_bins_never_hurt_much() {
        let mut r = Rng::new(11);
        let vals: Vec<f32> = (0..30_000).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let coarse = kmeans_hist(&vals, 3, 256);
        let fine = kmeans_hist(&vals, 3, 8192);
        let i_coarse = inertia_of(&vals, &coarse);
        let i_fine = inertia_of(&vals, &fine);
        assert!(i_fine <= i_coarse * 1.01);
    }
}
