//! Linear (affine) quantization — the paper's Eq. (1)–(3), plus packing,
//! per-channel granularity and the container types the pipeline moves
//! around.
//!
//!   Q(x) = INT(S·x) + Z            (1)
//!   S    = (2^b − 1) / (α − β)     (2)
//!   Z    = −2^(b−1) − INT(S·β)     (3)
//!
//! with `INT` = round-half-away-from-zero, values clamped to the signed
//! b-bit range [−2^(b−1), 2^(b−1)−1], and dequantization x̂ = (Q − Z)/S.
//!
//! One deliberate deviation from a literal reading of the paper: the
//! quantization range is widened to include 0 (`β ← min(β, 0)`,
//! `α ← max(α, 0)`). Q(0) = Z then dequantizes to exactly 0.0, which the
//! SplitQuantV2 masked-sum split depends on (split planes are ~2/3 zeros;
//! any error on them would inject dense noise). For full-tensor baseline
//! quantization of real weight matrices this is a no-op (ranges always
//! straddle 0).

pub mod pack;

use crate::tensor::{Tensor, TensorI8};
use anyhow::{bail, Result};

/// Supported bit widths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bits {
    Int2,
    Int4,
    Int8,
}

impl Bits {
    pub fn width(self) -> u32 {
        match self {
            Bits::Int2 => 2,
            Bits::Int4 => 4,
            Bits::Int8 => 8,
        }
    }

    pub fn from_width(w: usize) -> Result<Bits> {
        Ok(match w {
            2 => Bits::Int2,
            4 => Bits::Int4,
            8 => Bits::Int8,
            _ => bail!("unsupported bit width {w} (supported: 2, 4, 8)"),
        })
    }

    /// qmin = −2^(b−1).
    pub fn qmin(self) -> i32 {
        -(1 << (self.width() - 1))
    }

    /// qmax = 2^(b−1) − 1.
    pub fn qmax(self) -> i32 {
        (1 << (self.width() - 1)) - 1
    }

    /// Number of representable levels, 2^b.
    pub fn levels(self) -> u32 {
        1 << self.width()
    }

    pub fn name(self) -> &'static str {
        match self {
            Bits::Int2 => "INT2",
            Bits::Int4 => "INT4",
            Bits::Int8 => "INT8",
        }
    }
}

/// Round half away from zero — the `INT()` of the paper. (Rust's
/// `f32::round` already rounds half away from zero.)
#[inline]
pub fn int_round(x: f64) -> i64 {
    x.round() as i64
}

/// Affine quantization parameters for one tensor (or one channel).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub bits: Bits,
    /// Scaling factor S (Eq. 2). Larger S = finer resolution.
    pub scale: f64,
    /// Zero point Z (Eq. 3).
    pub zero_point: i32,
}

impl QuantParams {
    /// Derive parameters from a value range (Eq. 2–3), widening the range
    /// to include zero. `lo == hi == 0` degenerates to scale 1.
    pub fn from_range(bits: Bits, lo: f32, hi: f32) -> QuantParams {
        debug_assert!(lo <= hi, "invalid range [{lo}, {hi}]");
        let beta = (lo as f64).min(0.0);
        let alpha = (hi as f64).max(0.0);
        let width = alpha - beta;
        if width == 0.0 {
            // All-zero tensor: any scale represents it exactly via Q=Z.
            return QuantParams {
                bits,
                scale: 1.0,
                zero_point: 0,
            };
        }
        let scale = ((bits.levels() - 1) as f64) / width;
        let zero_point = (-(1i64 << (bits.width() - 1)) - int_round(scale * beta)) as i32;
        QuantParams {
            bits,
            scale,
            zero_point,
        }
    }

    /// Parameters covering a whole tensor.
    pub fn of_tensor(bits: Bits, t: &Tensor) -> QuantParams {
        QuantParams::from_range(bits, t.min(), t.max())
    }

    /// Quantize one value (Eq. 1), clamped to the representable range.
    #[inline]
    pub fn quantize(&self, x: f32) -> i8 {
        let q = int_round(self.scale * x as f64) + self.zero_point as i64;
        q.clamp(self.bits.qmin() as i64, self.bits.qmax() as i64) as i8
    }

    /// Dequantize one level: x̂ = (Q − Z)/S.
    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        ((q as i64 - self.zero_point as i64) as f64 / self.scale) as f32
    }

    /// The quantization step (resolution): 1/S. Half of this bounds the
    /// rounding error for in-range values.
    pub fn step(&self) -> f64 {
        1.0 / self.scale
    }
}

/// Quantization granularity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One (scale, zero_point) for the whole tensor — what the paper's
    /// baseline and SplitQuantV2 evaluation use.
    PerTensor,
    /// One (scale, zero_point) per output channel (row of a [out, in]
    /// weight matrix) — provided for ablations.
    PerChannel,
}

/// A quantized tensor: integer plane + parameters. The integer plane is
/// kept unpacked (i8) in memory for compute; [`pack`] produces the
/// storage representation.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub plane: TensorI8,
    pub granularity: Granularity,
    /// One entry for PerTensor; `rows` entries for PerChannel.
    pub params: Vec<QuantParams>,
}

impl QuantizedTensor {
    pub fn bits(&self) -> Bits {
        self.params[0].bits
    }

    pub fn shape(&self) -> &[usize] {
        self.plane.shape()
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Tensor {
        let shape = self.plane.shape().to_vec();
        let data = self.plane.data();
        match self.granularity {
            Granularity::PerTensor => {
                let p = self.params[0];
                Tensor::new(&shape, data.iter().map(|&q| p.dequantize(q)).collect())
            }
            Granularity::PerChannel => {
                assert_eq!(shape.len(), 2);
                let cols = shape[1];
                let mut out = Vec::with_capacity(data.len());
                for (r, chunk) in data.chunks_exact(cols).enumerate() {
                    let p = self.params[r];
                    out.extend(chunk.iter().map(|&q| p.dequantize(q)));
                }
                Tensor::new(&shape, out)
            }
        }
    }

    /// Bytes this tensor occupies when bit-packed for storage
    /// (plane only; params add a handful of bytes).
    pub fn packed_len(&self) -> usize {
        pack::packed_len(self.plane.len(), self.bits())
    }
}

/// Quantize a tensor with one scale/zero-point (the paper's scheme).
pub fn quantize_per_tensor(t: &Tensor, bits: Bits) -> QuantizedTensor {
    let p = QuantParams::of_tensor(bits, t);
    let plane = TensorI8::new(
        t.shape(),
        t.data().iter().map(|&x| p.quantize(x)).collect(),
    );
    QuantizedTensor {
        plane,
        granularity: Granularity::PerTensor,
        params: vec![p],
    }
}

/// Quantize a 2-D tensor row-wise (per output channel).
pub fn quantize_per_channel(t: &Tensor, bits: Bits) -> QuantizedTensor {
    assert_eq!(t.ndim(), 2, "per-channel requires a matrix");
    let (rows, cols) = (t.rows(), t.cols());
    let mut params = Vec::with_capacity(rows);
    let mut plane = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        let row = t.row(r);
        let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let p = QuantParams::from_range(bits, lo, hi);
        plane.extend(row.iter().map(|&x| p.quantize(x)));
        params.push(p);
    }
    QuantizedTensor {
        plane: TensorI8::new(t.shape(), plane),
        granularity: Granularity::PerChannel,
        params,
    }
}

/// Fake-quantization: quantize then dequantize (the standard simulated-
/// quantization used for accuracy evaluation; identical numerics to
/// executing the integer plane with dequant-on-load).
pub fn fake_quantize(t: &Tensor, bits: Bits) -> Tensor {
    quantize_per_tensor(t, bits).dequantize()
}

/// Quantization mean-squared-error of a tensor at a bit width — the
/// resolution metric Figure 1 visualizes.
pub fn quant_mse(t: &Tensor, bits: Bits) -> f64 {
    let q = fake_quantize(t, bits);
    crate::util::stats::mse(t.data(), q.data())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_ranges() {
        assert_eq!(Bits::Int8.qmin(), -128);
        assert_eq!(Bits::Int8.qmax(), 127);
        assert_eq!(Bits::Int4.qmin(), -8);
        assert_eq!(Bits::Int4.qmax(), 7);
        assert_eq!(Bits::Int2.qmin(), -2);
        assert_eq!(Bits::Int2.qmax(), 1);
        assert_eq!(Bits::Int4.levels(), 16);
        assert!(Bits::from_width(3).is_err());
        assert_eq!(Bits::from_width(4).unwrap(), Bits::Int4);
    }

    #[test]
    fn paper_formulas_hold() {
        // For range [-1, 3] at INT4: S = 15/4, Z = -8 - INT(-15/4) = -4.
        let p = QuantParams::from_range(Bits::Int4, -1.0, 3.0);
        assert!((p.scale - 15.0 / 4.0).abs() < 1e-12);
        assert_eq!(p.zero_point, -8 - (-(15.0f64 / 4.0)).round() as i32);
        // Extremes map to qmin/qmax.
        assert_eq!(p.quantize(-1.0), -8);
        assert_eq!(p.quantize(3.0), 7);
    }

    #[test]
    fn zero_is_exact_for_all_bit_widths() {
        let mut r = Rng::new(1);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            for _ in 0..50 {
                let lo = r.uniform_in(-5.0, 0.0);
                let hi = r.uniform_in(0.0, 5.0);
                let p = QuantParams::from_range(bits, lo, hi);
                assert_eq!(p.dequantize(p.quantize(0.0)), 0.0, "{bits:?} [{lo},{hi}]");
            }
            // Positive-only and negative-only ranges (widened to include 0).
            let p = QuantParams::from_range(bits, 2.0, 5.0);
            assert_eq!(p.dequantize(p.quantize(0.0)), 0.0);
            let p = QuantParams::from_range(bits, -5.0, -2.0);
            assert_eq!(p.dequantize(p.quantize(0.0)), 0.0);
        }
    }

    #[test]
    fn all_zero_tensor_roundtrips() {
        let t = Tensor::zeros(&[4, 4]);
        let q = quantize_per_tensor(&t, Bits::Int4);
        assert_eq!(q.dequantize(), t);
    }

    #[test]
    fn rounding_error_bounded_by_half_step() {
        let mut r = Rng::new(2);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let data: Vec<f32> = (0..1000).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let t = Tensor::from_vec(data);
            let p = QuantParams::of_tensor(bits, &t);
            let q = quantize_per_tensor(&t, bits);
            let dq = q.dequantize();
            let bound = 0.5 * p.step() + 1e-6;
            for (a, b) in t.data().iter().zip(dq.data()) {
                assert!(
                    ((a - b) as f64).abs() <= bound,
                    "{bits:?}: |{a} - {b}| > {bound}"
                );
            }
        }
    }

    #[test]
    fn int8_nearly_lossless_int2_lossy() {
        let mut r = Rng::new(3);
        let data: Vec<f32> = (0..2000).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let t = Tensor::from_vec(data);
        let e8 = quant_mse(&t, Bits::Int8);
        let e4 = quant_mse(&t, Bits::Int4);
        let e2 = quant_mse(&t, Bits::Int2);
        assert!(e8 < e4 && e4 < e2, "e8={e8} e4={e4} e2={e2}");
        assert!(e8 < 1e-3);
        assert!(e2 > 1e-2);
    }

    #[test]
    fn outliers_destroy_resolution() {
        // The paper's core motivation: one outlier inflates (α−β) and the
        // MSE of everything else. Removing it shrinks the step ~50x.
        let mut r = Rng::new(4);
        let mut data: Vec<f32> = (0..1000).map(|_| r.normal_f32(0.0, 0.1)).collect();
        let clean_step = QuantParams::of_tensor(Bits::Int4, &Tensor::from_vec(data.clone())).step();
        data.push(25.0);
        let dirty_step = QuantParams::of_tensor(Bits::Int4, &Tensor::from_vec(data)).step();
        assert!(dirty_step > clean_step * 20.0);
    }

    #[test]
    fn per_channel_no_worse_than_per_tensor() {
        let mut r = Rng::new(5);
        // Rows with very different scales.
        let mut data = Vec::new();
        for row in 0..8 {
            let s = 0.01 * (10.0f32).powi(row % 3);
            for _ in 0..32 {
                data.push(r.normal_f32(0.0, s));
            }
        }
        let t = Tensor::new(&[8, 32], data);
        let pt = quantize_per_tensor(&t, Bits::Int4).dequantize();
        let pc = quantize_per_channel(&t, Bits::Int4).dequantize();
        let mse_pt = crate::util::stats::mse(t.data(), pt.data());
        let mse_pc = crate::util::stats::mse(t.data(), pc.data());
        assert!(mse_pc <= mse_pt + 1e-12, "pc={mse_pc} pt={mse_pt}");
        assert!(mse_pc < mse_pt * 0.5, "per-channel should win big here");
    }

    #[test]
    fn quantize_clamps_out_of_range() {
        let p = QuantParams::from_range(Bits::Int4, -1.0, 1.0);
        assert_eq!(p.quantize(100.0), 7);
        assert_eq!(p.quantize(-100.0), -8);
    }

    #[test]
    fn dequantize_shape_preserved_per_channel() {
        let t = Tensor::new(&[3, 4], (0..12).map(|i| i as f32).collect());
        let q = quantize_per_channel(&t, Bits::Int8);
        assert_eq!(q.dequantize().shape(), &[3, 4]);
        assert_eq!(q.params.len(), 3);
        assert!(q.dequantize().allclose(&t, 0.05));
    }

    #[test]
    fn int_round_half_away_from_zero() {
        assert_eq!(int_round(0.5), 1);
        assert_eq!(int_round(-0.5), -1);
        assert_eq!(int_round(2.4), 2);
        assert_eq!(int_round(-2.6), -3);
    }
}
