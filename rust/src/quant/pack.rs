//! Bit-packing of quantized planes for storage.
//!
//! Signed b-bit levels are stored offset-binary (`q − qmin`, an unsigned
//! value in [0, 2^b)) and packed little-endian within each byte:
//! INT8 → 1 value/byte, INT4 → 2 values/byte (low nibble first), INT2 →
//! 4 values/byte (lowest 2 bits first). This is the on-disk and
//! reported-model-size representation (E4: the 1/8-vs-3/8 size table);
//! compute paths unpack to i8.

use super::Bits;
use anyhow::{bail, Result};

/// Values stored per packed byte at a bit width: 1 (INT8), 2 (INT4) or
/// 4 (INT2). Shared by the packers here and the LUT-fused kernels
/// (`crate::kernels`), whose byte tables hold exactly this many lanes
/// per entry.
pub fn lanes_per_byte(bits: Bits) -> usize {
    8 / bits.width() as usize
}

/// Bytes needed to pack `n` values at a bit width.
pub fn packed_len(n: usize, bits: Bits) -> usize {
    n.div_ceil(lanes_per_byte(bits))
}

/// Pack signed levels into bytes. Values must be within the bit width's
/// representable range.
pub fn pack(values: &[i8], bits: Bits) -> Vec<u8> {
    let qmin = bits.qmin();
    let width = bits.width() as usize;
    let per_byte = lanes_per_byte(bits);
    let mask = ((1u32 << width) - 1) as u8;
    let mut out = vec![0u8; packed_len(values.len(), bits)];
    for (i, &v) in values.iter().enumerate() {
        debug_assert!(
            (v as i32) >= qmin && (v as i32) <= bits.qmax(),
            "value {v} out of {bits:?} range"
        );
        let u = ((v as i32 - qmin) as u8) & mask;
        let byte = i / per_byte;
        let shift = (i % per_byte) * width;
        out[byte] |= u << shift;
    }
    out
}

/// Bytes per row of a row-aligned packed matrix: each row starts at a
/// byte boundary so kernels can address rows independently even when
/// `cols` is not a multiple of the values-per-byte count.
pub fn row_stride(cols: usize, bits: Bits) -> usize {
    packed_len(cols, bits)
}

/// Pack a row-major `[rows, cols]` plane with every row aligned to a
/// byte boundary (the layout [`crate::kernels`] executes directly).
/// Returns `rows * row_stride(cols, bits)` bytes.
pub fn pack_rows(values: &[i8], rows: usize, cols: usize, bits: Bits) -> Vec<u8> {
    assert_eq!(values.len(), rows * cols, "plane length != rows*cols");
    let stride = row_stride(cols, bits);
    let mut out = vec![0u8; rows * stride];
    for r in 0..rows {
        let packed = pack(&values[r * cols..(r + 1) * cols], bits);
        out[r * stride..r * stride + packed.len()].copy_from_slice(&packed);
    }
    out
}

/// Read one signed level out of a packed row (or any packed run) by
/// value index. Accessor for tests/tools; kernels unpack whole blocks.
pub fn get_packed(bytes: &[u8], i: usize, bits: Bits) -> i8 {
    let width = bits.width() as usize;
    let per_byte = lanes_per_byte(bits);
    let mask = ((1u32 << width) - 1) as u8;
    let u = (bytes[i / per_byte] >> ((i % per_byte) * width)) & mask;
    (u as i32 + bits.qmin()) as i8
}

/// Unpack `n` signed levels from packed bytes.
pub fn unpack(bytes: &[u8], n: usize, bits: Bits) -> Result<Vec<i8>> {
    let expect = packed_len(n, bits);
    if bytes.len() != expect {
        bail!(
            "packed length {} != expected {} for n={} at {:?}",
            bytes.len(),
            expect,
            n,
            bits
        );
    }
    let qmin = bits.qmin();
    let width = bits.width() as usize;
    let per_byte = lanes_per_byte(bits);
    let mask = ((1u32 << width) - 1) as u8;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let byte = bytes[i / per_byte];
        let shift = (i % per_byte) * width;
        let u = (byte >> shift) & mask;
        out.push((u as i32 + qmin) as i8);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lanes_per_byte_by_width() {
        assert_eq!(lanes_per_byte(Bits::Int8), 1);
        assert_eq!(lanes_per_byte(Bits::Int4), 2);
        assert_eq!(lanes_per_byte(Bits::Int2), 4);
    }

    #[test]
    fn lengths() {
        assert_eq!(packed_len(8, Bits::Int8), 8);
        assert_eq!(packed_len(8, Bits::Int4), 4);
        assert_eq!(packed_len(8, Bits::Int2), 2);
        // Ragged tails round up.
        assert_eq!(packed_len(9, Bits::Int4), 5);
        assert_eq!(packed_len(5, Bits::Int2), 2);
        assert_eq!(packed_len(0, Bits::Int2), 0);
    }

    #[test]
    fn roundtrip_all_values_all_widths() {
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let all: Vec<i8> = (bits.qmin()..=bits.qmax()).map(|v| v as i8).collect();
            let packed = pack(&all, bits);
            let back = unpack(&packed, all.len(), bits).unwrap();
            assert_eq!(back, all, "{bits:?}");
        }
    }

    #[test]
    fn roundtrip_random_at_all_alignments() {
        let mut r = Rng::new(1);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            for n in 0..35 {
                let vals: Vec<i8> = (0..n)
                    .map(|_| {
                        (bits.qmin() + r.below((bits.qmax() - bits.qmin() + 1) as usize) as i32)
                            as i8
                    })
                    .collect();
                let packed = pack(&vals, bits);
                assert_eq!(packed.len(), packed_len(n, bits));
                assert_eq!(unpack(&packed, n, bits).unwrap(), vals, "{bits:?} n={n}");
            }
        }
    }

    #[test]
    fn int4_nibble_order_is_low_first() {
        // values [-8, 7]: offsets [0, 15] -> byte 0xF0.
        let packed = pack(&[-8, 7], Bits::Int4);
        assert_eq!(packed, vec![0xF0]);
    }

    #[test]
    fn int2_bit_order() {
        // offsets of [-2,-1,0,1] are [0,1,2,3] -> 0b11_10_01_00 = 0xE4.
        let packed = pack(&[-2, -1, 0, 1], Bits::Int2);
        assert_eq!(packed, vec![0xE4]);
    }

    #[test]
    fn unpack_rejects_wrong_length() {
        assert!(unpack(&[0u8; 3], 8, Bits::Int4).is_err());
    }

    #[test]
    fn row_aligned_packing_roundtrips_odd_cols() {
        let mut r = Rng::new(2);
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            for (rows, cols) in [(3usize, 5usize), (1, 7), (4, 1), (2, 8)] {
                let vals: Vec<i8> = (0..rows * cols)
                    .map(|_| {
                        (bits.qmin() + r.below((bits.qmax() - bits.qmin() + 1) as usize) as i32)
                            as i8
                    })
                    .collect();
                let stride = row_stride(cols, bits);
                let bytes = pack_rows(&vals, rows, cols, bits);
                assert_eq!(bytes.len(), rows * stride);
                for row in 0..rows {
                    let rb = &bytes[row * stride..(row + 1) * stride];
                    for c in 0..cols {
                        assert_eq!(
                            get_packed(rb, c, bits),
                            vals[row * cols + c],
                            "{bits:?} [{rows}x{cols}] ({row},{c})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn compression_ratios_match_paper() {
        // FP32 -> INT4 is 1/8 of the bytes; INT2 is 1/16.
        let n = 1024;
        assert_eq!(packed_len(n, Bits::Int4) * 8, n * 4);
        assert_eq!(packed_len(n, Bits::Int2) * 16, n * 4);
    }
}
