//! Log-bucketed, mergeable, thread-sharded histograms.
//!
//! The bucket scheme is log-linear (HdrHistogram-style): values
//! `0..=3` get exact single-value buckets, and every power-of-two
//! octave `[2^e, 2^(e+1))` for `e >= 2` is split into 4 equal
//! sub-buckets keyed by the two mantissa bits after the leading one.
//! Relative bucket width is therefore at most 25%, which bounds the
//! error of [`HistData::percentile`] against a sorted-vector oracle
//! (`util::stats::percentile_sorted`) — the contract pinned by
//! `tests/obs_metrics.rs`.
//!
//! Recording is 3 relaxed `fetch_add`s (bucket, count, sum) on the
//! calling thread's shard; shards merge losslessly at snapshot time,
//! so totals are exact once recorders quiesce even though recording
//! never takes a lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::{enabled, shard_index, SHARDS};

/// Number of buckets: 4 exact small values plus 4 sub-buckets for each
/// of the 62 octaves `[2^2, 2^64)`.
pub const BUCKETS: usize = 252;

/// Map a value to its bucket index. Monotonic in `v`.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros() as usize; // 2..=63
    let sub = ((v >> (exp - 2)) & 0b11) as usize;
    ((exp - 1) << 2) | sub
}

/// Inclusive lower / exclusive upper value bounds of bucket `b`
/// (saturating at `u64::MAX` for the topmost bucket).
pub fn bucket_bounds(b: usize) -> (u64, u64) {
    if b < 4 {
        return (b as u64, b as u64 + 1);
    }
    let exp = (b >> 2) + 1;
    let sub = (b & 0b11) as u64;
    let lo = (4 + sub) << (exp - 2);
    let width = 1u64 << (exp - 2);
    (lo, lo.saturating_add(width))
}

struct Shard {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Shard {
    fn new() -> Self {
        Shard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// A log-bucketed histogram sharded across [`SHARDS`] per-thread
/// slots. Clones share the same shards; durations are recorded in
/// nanoseconds by convention (`*_ns` metric names).
#[derive(Clone)]
pub struct Histogram {
    shards: Arc<Vec<Shard>>,
}

impl Histogram {
    pub(crate) fn new() -> Self {
        Histogram {
            shards: Arc::new((0..SHARDS).map(|_| Shard::new()).collect()),
        }
    }

    /// Record one observation. No-op while recording is disabled.
    #[inline]
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        let s = &self.shards[shard_index()];
        s.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        s.count.fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration as nanoseconds (saturating at `u64::MAX`).
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Fold every shard into a point-in-time [`HistData`].
    pub fn merged(&self) -> HistData {
        let mut buckets = vec![0u64; BUCKETS];
        let mut count = 0u64;
        let mut sum = 0u64;
        for s in self.shards.iter() {
            for (b, a) in buckets.iter_mut().zip(s.buckets.iter()) {
                *b += a.load(Ordering::Relaxed);
            }
            count += s.count.load(Ordering::Relaxed);
            sum = sum.wrapping_add(s.sum.load(Ordering::Relaxed));
        }
        HistData { buckets, count, sum }
    }
}

/// Point-in-time merged histogram contents.
#[derive(Clone, Debug)]
pub struct HistData {
    /// Per-bucket observation counts (bounds via [`bucket_bounds`]).
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of observed values.
    pub sum: u64,
}

impl HistData {
    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Estimate the `p`-th percentile (0..=100): the value of the
    /// bucket holding that rank — exact for values `0..=3`, the bucket
    /// midpoint above (within the ≤25% relative bucket width of the
    /// true sorted-vector percentile). 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 100.0) / 100.0) * (self.count as f64 - 1.0);
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            if cum as f64 > rank {
                let (lo, hi) = bucket_bounds(b);
                if b < 4 {
                    return lo as f64;
                }
                return (lo as f64 + hi as f64) / 2.0;
            }
        }
        // Unreachable: count > 0 means some bucket crossed the rank.
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotonic_and_bounds_contain_values() {
        let mut values: Vec<u64> = Vec::new();
        for shift in 0..64u32 {
            for off in [0u64, 1, 3] {
                values.push((1u64 << shift).saturating_add(off));
            }
        }
        values.push(u64::MAX);
        values.sort_unstable();
        let mut prev = 0usize;
        for v in values {
            let b = bucket_of(v);
            assert!(b < BUCKETS);
            assert!(b >= prev, "bucket_of must be monotonic (v={v})");
            prev = b;
            let (lo, hi) = bucket_bounds(b);
            assert!(
                lo <= v && (v < hi || hi == u64::MAX),
                "v={v} b={b} lo={lo} hi={hi}"
            );
        }
    }

    #[test]
    fn small_values_get_exact_buckets() {
        for v in 0u64..4 {
            assert_eq!(bucket_of(v), v as usize);
            assert_eq!(bucket_bounds(v as usize), (v, v + 1));
        }
        // First octave [4,8) is also exact: width-1 sub-buckets.
        for v in 4u64..8 {
            let (lo, hi) = bucket_bounds(bucket_of(v));
            assert_eq!((lo, hi), (v, v + 1));
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        let h = Histogram::new();
        let d = h.merged();
        assert_eq!(d.count, 0);
        assert_eq!(d.mean(), 0.0);
        assert_eq!(d.percentile(50.0), 0.0);
    }

    #[test]
    fn merged_totals_are_exact() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 4, 100, 1000, 1_000_000] {
            h.record(v);
        }
        let d = h.merged();
        assert_eq!(d.count, 8);
        assert_eq!(d.sum, 1_001_110);
    }
}
