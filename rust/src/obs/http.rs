//! Live `/metrics` exposition on a std `TcpListener`.
//!
//! [`serve`] binds an address and answers scrapes from a background
//! thread with a deliberately minimal HTTP/1.1 implementation (parse
//! the request line, write one response, close). Routes:
//!
//! * `GET /metrics` — Prometheus text format (content type 0.0.4)
//! * `GET /metrics.json` (or `/metrics?format=json`) — the JSON
//!   snapshot, identical to what `--metrics-json` dumps
//!
//! Dropping the returned [`MetricsServer`] stops the listener: the
//! drop sets a stop flag and pokes the socket with a local connection
//! so the blocking `accept` wakes up and the thread joins. Scrapes are
//! handled sequentially — a metrics endpoint sees one scraper, not
//! traffic — and each connection gets a short read timeout so a stuck
//! client cannot wedge the loop.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::snapshot;
use crate::util::failpoint;

/// Handle to a running metrics endpoint; dropping it stops the
/// listener thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// The bound address (resolves `:0` port requests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so the blocking accept observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:9100`, or port 0 for an ephemeral
/// port) and serve metrics scrapes until the handle is dropped.
pub fn serve(addr: &str) -> Result<MetricsServer> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding metrics endpoint on {addr}"))?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let thread_stop = stop.clone();
    let handle = std::thread::Builder::new()
        .name("metrics-http".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if thread_stop.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    // One bad connection (or an injected fault) must not
                    // take the endpoint down: contain panics to this
                    // scrape and keep listening.
                    let contained = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if let Some(msg) = failpoint::trigger(failpoint::sites::METRICS_ACCEPT) {
                            let _ = respond_error(&stream, &msg);
                            return;
                        }
                        let _ = handle_conn(stream);
                    }));
                    if contained.is_err() {
                        crate::obs::counter("metrics_http_panics_total").inc();
                    }
                }
            }
        })
        .context("spawning metrics endpoint thread")?;
    Ok(MetricsServer {
        addr: local,
        stop,
        handle: Some(handle),
    })
}

/// Answer a scrape with a 500 carrying the injected-fault message.
fn respond_error(mut stream: &TcpStream, msg: &str) -> std::io::Result<()> {
    let body = format!("{msg}\n");
    let header = format!(
        "HTTP/1.1 500 Internal Server Error\r\nContent-Type: text/plain\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())
}

fn handle_conn(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    // Read just enough to see the request line; anything else (headers,
    // bodies) is irrelevant to a scrape and is dropped with the socket.
    let mut buf = [0u8; 1024];
    let mut n = 0;
    while n < buf.len() {
        let r = stream.read(&mut buf[n..])?;
        if r == 0 {
            break;
        }
        n += r;
        if buf[..n].windows(2).any(|w| w == b"\r\n") {
            break;
        }
    }
    let line = String::from_utf8_lossy(&buf[..n]);
    let path = line.split_whitespace().nth(1).unwrap_or("");
    let (status, ctype, body) = route(path);
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())
}

fn route(path: &str) -> (&'static str, &'static str, String) {
    match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            snapshot().to_prometheus(),
        ),
        "/metrics.json" | "/metrics?format=json" => (
            "200 OK",
            "application/json",
            snapshot().to_json().to_string_pretty(),
        ),
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn endpoint_serves_prometheus_and_json_and_stops_on_drop() {
        let _g = obs::test_guard();
        obs::set_enabled(true);
        obs::counter("obs_http_test_total").add(5);
        let server = serve("127.0.0.1:0").unwrap();
        let addr = server.addr();

        let text = get(addr, "/metrics");
        assert!(text.starts_with("HTTP/1.1 200 OK"));
        assert!(text.contains("obs_http_test_total 5"));

        let json = get(addr, "/metrics.json");
        assert!(json.starts_with("HTTP/1.1 200 OK"));
        assert!(json.contains("\"obs_http_test_total\""));

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"));

        drop(server);
        // The listener thread has joined and the socket is closed, so
        // new connections are refused.
        assert!(
            TcpStream::connect(addr).is_err(),
            "listener must shut down on drop"
        );
    }
}
