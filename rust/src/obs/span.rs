//! Lightweight RAII span tracing with per-thread ring buffers.
//!
//! A span is a named scope: [`crate::span!`] returns a guard whose
//! drop records `{name, depth, start, duration}` into the calling
//! thread's fixed-capacity ring (newest overwrites oldest). Spans
//! nest: the guard captures the thread's depth at entry, so a
//! `decode_step` opened inside `serve_batch` shows up one level
//! deeper. Rings register themselves in a global list on first use;
//! [`recent_spans`] folds every thread's ring into one
//! start-ordered trace, and snapshots embed it in their JSON.
//!
//! Cost model: while recording is disabled the guard is fully inert —
//! no clock read, no allocation. Enabled, entry is one `Instant::now`
//! plus a thread-local depth bump; exit adds the record under the
//! ring's own (uncontended, per-thread) mutex. Spans therefore sit on
//! *phase* boundaries (pipeline stages, prefill/decode, scheduling
//! passes) — per-GEMV kernel activity is counted by the much cheaper
//! sharded counters instead, which is how the ≤3% overhead contract
//! on the decode hot path holds.

use std::cell::Cell;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::{enabled, registry};

/// One completed span, as recorded by a dropped guard.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    /// Static span name as passed to [`crate::span!`].
    pub name: &'static str,
    /// Nesting depth at entry on the recording thread (0 = top-level).
    pub depth: u16,
    /// Start offset from the registry origin, in nanoseconds.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
}

/// Capacity of each per-thread ring buffer.
pub const RING_CAPACITY: usize = 256;

struct Ring {
    buf: Vec<SpanRecord>,
    next: usize,
}

static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL_RING: Arc<Mutex<Ring>> = {
        let r = Arc::new(Mutex::new(Ring {
            buf: Vec::with_capacity(RING_CAPACITY),
            next: 0,
        }));
        RINGS.lock().unwrap().push(r.clone());
        r
    };
    static DEPTH: Cell<u16> = const { Cell::new(0) };
}

/// An RAII span guard: create via [`crate::span!`] and **bind it to a
/// variable** (`let _span = span!("decode_step");`) so it lives to the
/// end of the scope; `let _ =` would drop it immediately. Inert while
/// recording is disabled.
pub struct Span {
    name: &'static str,
    start: Option<Instant>,
    depth: u16,
}

impl Span {
    /// Enter a span. Prefer the [`crate::span!`] macro.
    pub fn enter(name: &'static str) -> Span {
        if !enabled() {
            return Span {
                name,
                start: None,
                depth: 0,
            };
        }
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v.saturating_add(1));
            v
        });
        Span {
            name,
            start: Some(Instant::now()),
            depth,
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let origin = registry().start_instant();
        let rec = SpanRecord {
            name: self.name,
            depth: self.depth,
            start_ns: dur_to_ns(start.saturating_duration_since(origin)),
            dur_ns: dur_to_ns(start.elapsed()),
        };
        LOCAL_RING.with(|r| {
            let mut ring = r.lock().unwrap();
            if ring.buf.len() < RING_CAPACITY {
                ring.buf.push(rec);
            } else {
                let i = ring.next;
                ring.buf[i] = rec;
            }
            ring.next = (ring.next + 1) % RING_CAPACITY;
        });
    }
}

fn dur_to_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// Fold every thread's ring into one trace, ordered by start offset.
pub fn recent_spans() -> Vec<SpanRecord> {
    let rings = RINGS.lock().unwrap();
    let mut out = Vec::new();
    for r in rings.iter() {
        let ring = r.lock().unwrap();
        out.extend(ring.buf.iter().copied());
    }
    drop(rings);
    out.sort_by_key(|s| s.start_ns);
    out
}

/// Enter a named tracing span for the current scope. Returns a
/// [`Span`] guard — bind it (`let _span = splitquant::span!("x");`);
/// the span is recorded when the guard drops.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::span::Span::enter($name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_record_on_drop() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        {
            let _outer = crate::span!("obs_span_test_outer");
            let _inner = crate::span!("obs_span_test_inner");
        }
        let spans = recent_spans();
        let outer = spans
            .iter()
            .find(|s| s.name == "obs_span_test_outer")
            .expect("outer span recorded");
        let inner = spans
            .iter()
            .find(|s| s.name == "obs_span_test_inner")
            .expect("inner span recorded");
        assert_eq!(inner.depth, outer.depth + 1, "inner nests under outer");
        assert!(inner.start_ns >= outer.start_ns);
        assert!(inner.dur_ns <= outer.dur_ns);
    }

    #[test]
    fn disabled_spans_leave_no_records() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        {
            let _s = crate::span!("obs_span_test_disabled");
        }
        crate::obs::set_enabled(true);
        assert!(
            !recent_spans().iter().any(|s| s.name == "obs_span_test_disabled"),
            "disabled span must not record"
        );
    }
}
