//! Zero-dependency telemetry: a process-global metrics registry,
//! lightweight span tracing, and Prometheus/JSON exposition
//! (DESIGN.md §10).
//!
//! The design goals, in order:
//!
//! 1. **Near-free when disabled.** Every recording call is gated on a
//!    single relaxed [`AtomicBool`] load ([`enabled`]); nothing else
//!    runs — no clock reads, no allocation, no locks. The toggle is
//!    runtime-switchable ([`set_enabled`]) so the same binary serves
//!    both instrumented replicas and bare benchmark runs, and
//!    `ci/check_bench_regression.py --max-metrics-overhead` gates the
//!    *enabled* cost on the INT4 decode path at ≤ 3%.
//! 2. **A single relaxed atomic op on the hot path.** Counters and
//!    histograms are sharded across cache-line-padded slots indexed by
//!    a per-thread id, so concurrent recorders never contend on one
//!    cache line; shards are folded only at snapshot time.
//! 3. **Zero dependencies.** Everything here is `std`: atomics,
//!    `OnceLock`, `TcpListener` for the [`http`] endpoint, and the
//!    crate's own [`crate::util::json::Json`] for the JSON exposition.
//!
//! Handles ([`Counter`], [`Gauge`], [`hist::Histogram`]) are cheap
//! `Arc` clones; instrumented code looks them up once (the lookup
//! takes the registry lock) and stores them, typically in a
//! `OnceLock`'d struct next to the hot path.
//!
//! Span tracing ([`span`], the [`crate::span!`] macro) records RAII
//! scope durations into per-thread ring buffers; recent spans ride
//! along in every [`MetricsSnapshot`].

pub mod hist;
pub mod http;
pub mod span;

mod expose;

pub use expose::{snapshot, CounterSample, GaugeSample, HistSample, MetricsSnapshot};
pub use hist::{HistData, Histogram};
pub use span::{recent_spans, Span, SpanRecord};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Canonical metric names used by the built-in instrumentation, so
/// tests, dashboards, and the hot paths all address the same series.
pub mod names {
    /// Gauge: request-queue depth sampled by the serve loop every
    /// scheduling pass.
    pub const SERVE_QUEUE_DEPTH: &str = "serve_queue_depth";
    /// Gauge: live generation sessions in the continuous batch.
    pub const SERVE_SESSIONS_ACTIVE: &str = "serve_sessions_active";
    /// Counter: generation sessions admitted into the batch.
    pub const SERVE_ADMISSIONS_TOTAL: &str = "serve_admissions_total";
    /// Counter: scoring requests executed to completion.
    pub const SERVE_SCORE_REQUESTS_TOTAL: &str = "serve_score_requests_total";
    /// Counter (labeled `reason`): requests shed with a typed
    /// `ServeError` — `overloaded`, `deadline`, `kv_exhausted`,
    /// `unsupported`, `invalid`, `internal`, `shutting_down`.
    pub const SERVE_SHED_TOTAL: &str = "serve_shed_total";
    /// Counter: worker panics contained by the serving core (each one
    /// also sheds exactly one request as `internal`).
    pub const SERVER_PANICS_TOTAL: &str = "server_panics_total";
    /// Counter: sessions cancelled by the decode-step watchdog (each
    /// one also sheds as `internal`).
    pub const WATCHDOG_CANCELLATIONS_TOTAL: &str = "watchdog_cancellations_total";
    /// Histogram (ns): time to first token (queue + prefill).
    pub const SERVE_TTFT_NS: &str = "serve_ttft_ns";
    /// Histogram (ns): total request latency (queue + prefill + decode).
    pub const SERVE_LATENCY_NS: &str = "serve_latency_ns";
    /// Counter: tokens streamed by generation sessions.
    pub const SERVE_TOKENS_TOTAL: &str = "serve_generated_tokens_total";
    /// Counter: prompt-prefix cache hits.
    pub const PREFIX_CACHE_HITS: &str = "prefix_cache_hits_total";
    /// Counter: prompt-prefix cache misses.
    pub const PREFIX_CACHE_MISSES: &str = "prefix_cache_misses_total";
    /// Gauge: K/V arena blocks currently rented (peak = high-water mark).
    pub const KV_BLOCKS_IN_USE: &str = "kv_blocks_in_use";
    /// Counter: K/V arena allocations refused because the arena was at
    /// capacity.
    pub const KV_RESERVATION_FAILURES: &str = "kv_reservation_failures_total";
    /// Counter (labeled `impl`): packed-plane kernel dispatches per
    /// effective `KernelImpl`.
    pub const KERNEL_DISPATCH_TOTAL: &str = "kernel_dispatch_total";
    /// Counter (labeled `impl`): output rows × sequence positions
    /// produced per effective `KernelImpl`.
    pub const KERNEL_ROWS_TOTAL: &str = "kernel_rows_total";
    /// Counter: lookup tables built (cache misses in `LutCache`).
    pub const KERNEL_LUT_BUILDS_TOTAL: &str = "kernel_lut_builds_total";
    /// Gauge (labeled `requested`/`resolved`, set to 1): records the
    /// dispatch decision, including silent-fallback cases where a
    /// forced `simd` resolves to `lut` on an incapable host.
    pub const KERNEL_RESOLVED_IMPL: &str = "kernel_resolved_impl";
    /// Counter (labeled `stage`, ns): pipeline stage time folded from
    /// `PipelineReport` — `cluster`, `quantize`, `pack`.
    pub const PIPELINE_STAGE_NS_TOTAL: &str = "pipeline_stage_ns_total";
    /// Counter: tensor units processed by the quantization pipeline.
    pub const PIPELINE_UNITS_TOTAL: &str = "pipeline_units_total";
    /// Counter: tokens proposed by the speculative draft engine
    /// (`model::specdec`). Acceptance rate =
    /// `specdec_accepted_tokens_total / specdec_draft_tokens_total`.
    pub const SPECDEC_DRAFT_TOKENS: &str = "specdec_draft_tokens_total";
    /// Counter: draft tokens accepted by the target verify pass.
    pub const SPECDEC_ACCEPTED_TOKENS: &str = "specdec_accepted_tokens_total";
    /// Counter: draft/verify rounds executed (each emits ≥1 token).
    pub const SPECDEC_ROUNDS: &str = "specdec_rounds_total";
    /// Histogram: accepted-run length per round (0..=k draft tokens
    /// accepted before the first mismatch; the bonus token from the
    /// verify pass is not counted).
    pub const SPECDEC_ACCEPT_LEN: &str = "specdec_accept_len";
}

// ---------------------------------------------------------------------
// Enable gate
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn hot-path recording on or off at runtime. Disabled is the
/// default; `--metrics-addr`/`--metrics-json` and the perf probe's
/// metrics tier switch it on. Gauges keep whatever value they last
/// recorded while enabled.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Whether recording is enabled — the single relaxed load every
/// recording call starts (and, when disabled, ends) with.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------
// Per-thread shard selection
// ---------------------------------------------------------------------

/// Shard count for counters and histograms. Threads map onto shards by
/// a monotonically assigned id, so up to [`SHARDS`] concurrent
/// recorders proceed with zero cache-line contention.
pub(crate) const SHARDS: usize = 16;

static NEXT_THREAD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_SHARD: usize =
        NEXT_THREAD.fetch_add(1, Ordering::Relaxed) % SHARDS;
}

#[inline]
pub(crate) fn shard_index() -> usize {
    THREAD_SHARD.with(|s| *s)
}

/// A cache-line-padded atomic shard: padding keeps neighbouring shards
/// on distinct lines so relaxed `fetch_add`s from different threads
/// never false-share.
#[repr(align(64))]
#[derive(Default)]
pub(crate) struct PadU64(pub(crate) AtomicU64);

// ---------------------------------------------------------------------
// Counter
// ---------------------------------------------------------------------

/// A monotonically increasing counter. [`Counter::add`] is one relaxed
/// `fetch_add` on the calling thread's padded shard; [`Counter::value`]
/// folds the shards. Clones share the same underlying shards.
#[derive(Clone)]
pub struct Counter {
    shards: Arc<[PadU64; SHARDS]>,
}

impl Counter {
    fn new() -> Self {
        Counter {
            shards: Arc::new(std::array::from_fn(|_| PadU64::default())),
        }
    }

    /// Add 1. No-op while recording is disabled.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `v`. No-op while recording is disabled.
    #[inline]
    pub fn add(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.shards[shard_index()].0.fetch_add(v, Ordering::Relaxed);
    }

    /// Current total across all shards. Exact once concurrent
    /// recorders have quiesced (each increment lands in exactly one
    /// shard; the fold loses nothing).
    pub fn value(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

// ---------------------------------------------------------------------
// Gauge
// ---------------------------------------------------------------------

struct GaugeInner {
    value: AtomicI64,
    peak: AtomicI64,
}

/// A last-value gauge with a high-water mark. Updates are single
/// relaxed stores; there is no sharding because gauges record state,
/// not events, and their call sites (queue depth per scheduling pass,
/// arena occupancy per block transition) are not per-token hot.
#[derive(Clone)]
pub struct Gauge {
    inner: Arc<GaugeInner>,
}

impl Gauge {
    fn new() -> Self {
        Gauge {
            inner: Arc::new(GaugeInner {
                value: AtomicI64::new(0),
                peak: AtomicI64::new(0),
            }),
        }
    }

    /// Set the current value. No-op while recording is disabled.
    #[inline]
    pub fn set(&self, v: i64) {
        if !enabled() {
            return;
        }
        self.set_always(v);
    }

    /// Set even while recording is disabled — for configuration-style
    /// gauges (e.g. the resolved kernel impl) that must be visible in
    /// the first snapshot no matter when recording was switched on.
    pub fn set_always(&self, v: i64) {
        self.inner.value.store(v, Ordering::Relaxed);
        self.inner.peak.fetch_max(v, Ordering::Relaxed);
    }

    /// Apply a signed delta. No-op while recording is disabled.
    #[inline]
    pub fn add(&self, d: i64) {
        if !enabled() {
            return;
        }
        let v = self.inner.value.fetch_add(d, Ordering::Relaxed) + d;
        self.inner.peak.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn value(&self) -> i64 {
        self.inner.value.load(Ordering::Relaxed)
    }

    /// Highest value ever recorded (high-water mark).
    pub fn peak(&self) -> i64 {
        self.inner.peak.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// The process-global registry behind [`counter`], [`gauge`], and
/// [`histogram`]. Keys are `(base name, rendered label pairs)`; a
/// `BTreeMap` keeps exposition order deterministic.
pub struct MetricsRegistry {
    start: Instant,
    pub(crate) counters: Mutex<BTreeMap<(String, String), Counter>>,
    pub(crate) gauges: Mutex<BTreeMap<(String, String), Gauge>>,
    pub(crate) hists: Mutex<BTreeMap<(String, String), Histogram>>,
}

impl MetricsRegistry {
    fn new() -> Self {
        MetricsRegistry {
            start: Instant::now(),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
        }
    }

    /// Time since the registry was first touched; span start offsets
    /// and snapshot `uptime` are measured from this origin.
    pub fn uptime(&self) -> Duration {
        self.start.elapsed()
    }

    pub(crate) fn start_instant(&self) -> Instant {
        self.start
    }
}

/// The global registry (created on first use).
pub fn registry() -> &'static MetricsRegistry {
    static REG: OnceLock<MetricsRegistry> = OnceLock::new();
    REG.get_or_init(MetricsRegistry::new)
}

/// Get or register the unlabeled counter `name`.
pub fn counter(name: &str) -> Counter {
    counter_with(name, &[])
}

/// Get or register a counter with label pairs, e.g.
/// `counter_with(names::SERVE_SHED_TOTAL, &[("reason", "overloaded")])`.
pub fn counter_with(name: &str, labels: &[(&str, &str)]) -> Counter {
    let key = (name.to_string(), labels_inner(labels));
    // Registry locks recover from poison: entries are only inserted
    // while consistent, so a panic under the lock (e.g. an injected
    // fault unwinding through instrumentation) leaves a valid map.
    let mut map = registry().counters.lock().unwrap_or_else(|e| e.into_inner());
    map.entry(key).or_insert_with(Counter::new).clone()
}

/// Get or register the unlabeled gauge `name`.
pub fn gauge(name: &str) -> Gauge {
    gauge_with(name, &[])
}

/// Get or register a gauge with label pairs.
pub fn gauge_with(name: &str, labels: &[(&str, &str)]) -> Gauge {
    let key = (name.to_string(), labels_inner(labels));
    let mut map = registry().gauges.lock().unwrap_or_else(|e| e.into_inner());
    map.entry(key).or_insert_with(Gauge::new).clone()
}

/// Get or register the unlabeled histogram `name`. Durations are
/// recorded in nanoseconds by convention (`*_ns` names).
pub fn histogram(name: &str) -> Histogram {
    histogram_with(name, &[])
}

/// Get or register a histogram with label pairs.
pub fn histogram_with(name: &str, labels: &[(&str, &str)]) -> Histogram {
    let key = (name.to_string(), labels_inner(labels));
    let mut map = registry().hists.lock().unwrap_or_else(|e| e.into_inner());
    map.entry(key).or_insert_with(Histogram::new).clone()
}

/// Render the full series name (`base{k="v"}`) exactly as the
/// Prometheus exposition prints it — the addressing scheme for
/// [`MetricsSnapshot`] lookups.
pub fn series(name: &str, labels: &[(&str, &str)]) -> String {
    let inner = labels_inner(labels);
    if inner.is_empty() {
        name.to_string()
    } else {
        format!("{name}{{{inner}}}")
    }
}

/// Render label pairs to the inside of a Prometheus brace block
/// (`k="v",k2="v2"`), escaping values per the text format.
pub(crate) fn labels_inner(labels: &[(&str, &str)]) -> String {
    let mut s = String::new();
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push_str("=\"");
        s.push_str(&escape_label(v));
        s.push('"');
    }
    s
}

/// Escape a label value per the Prometheus text format: `\` → `\\`,
/// `"` → `\"`, newline → `\n`.
pub(crate) fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Serializes unit tests that touch the process-global enabled flag,
/// so one test's disabled window cannot swallow another's recordings.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_inert_when_disabled_and_exact_when_enabled() {
        let _g = test_guard();
        set_enabled(false);
        let c = counter("obs_mod_test_counter");
        c.inc();
        c.add(41);
        assert_eq!(c.value(), 0, "disabled recording must be a no-op");
        set_enabled(true);
        c.inc();
        c.add(41);
        assert_eq!(c.value(), 42);
        // Handles to the same name share state.
        assert_eq!(counter("obs_mod_test_counter").value(), 42);
    }

    #[test]
    fn gauge_tracks_value_and_peak() {
        let _g = test_guard();
        set_enabled(true);
        let g = gauge("obs_mod_test_gauge");
        g.set(5);
        g.add(3);
        g.add(-6);
        assert_eq!(g.value(), 2);
        assert_eq!(g.peak(), 8);
    }

    #[test]
    fn labeled_series_are_distinct_and_render_escaped() {
        let _g = test_guard();
        set_enabled(true);
        let a = counter_with("obs_mod_test_labeled", &[("k", "a")]);
        let b = counter_with("obs_mod_test_labeled", &[("k", "b")]);
        a.inc();
        assert_eq!(a.value(), 1);
        assert_eq!(b.value(), 0);
        assert_eq!(
            series("m", &[("path", "a\\b\"c\nd")]),
            "m{path=\"a\\\\b\\\"c\\nd\"}"
        );
        assert_eq!(series("m", &[]), "m");
    }
}
