//! Metric exposition: point-in-time snapshots rendered as Prometheus
//! text format or JSON.
//!
//! [`snapshot`] folds every registered series (plus recent spans) into
//! a [`MetricsSnapshot`]; [`MetricsSnapshot::to_prometheus`] renders
//! the text format served on `/metrics` and
//! [`MetricsSnapshot::to_json`] the JSON served on `/metrics.json` and
//! dumped by `--metrics-json`.
//!
//! Histograms render in the standard Prometheus shape — cumulative
//! `_bucket{le="..."}` series plus `_sum`/`_count` — with `le` bounds
//! that are exact for the integer observations this crate records
//! (nanoseconds, counts). Gauges additionally expose their high-water
//! mark as a parallel `<name>_peak` gauge family.

use std::time::Duration;

use crate::util::json::Json;

use super::hist::{bucket_bounds, HistData};
use super::registry;
use super::span::{recent_spans, SpanRecord};

/// One counter series in a snapshot.
#[derive(Clone, Debug)]
pub struct CounterSample {
    /// Base metric name.
    pub name: String,
    /// Rendered label pairs (the inside of the `{}` block; may be empty).
    pub labels: String,
    /// Folded value at capture time.
    pub value: u64,
}

/// One gauge series in a snapshot (last value + high-water mark).
#[derive(Clone, Debug)]
pub struct GaugeSample {
    /// Base metric name.
    pub name: String,
    /// Rendered label pairs (may be empty).
    pub labels: String,
    /// Value at capture time.
    pub value: i64,
    /// Highest value ever recorded.
    pub peak: i64,
}

/// One histogram series in a snapshot.
#[derive(Clone, Debug)]
pub struct HistSample {
    /// Base metric name.
    pub name: String,
    /// Rendered label pairs (may be empty).
    pub labels: String,
    /// Merged bucket contents at capture time.
    pub data: HistData,
}

/// A point-in-time copy of every registered metric plus recent spans.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Process uptime (since the registry was first touched).
    pub uptime: Duration,
    /// All counter series, sorted by (name, labels).
    pub counters: Vec<CounterSample>,
    /// All gauge series, sorted by (name, labels).
    pub gauges: Vec<GaugeSample>,
    /// All histogram series, sorted by (name, labels).
    pub hists: Vec<HistSample>,
    /// Recent spans from every thread's ring buffer, start-ordered.
    pub spans: Vec<SpanRecord>,
}

/// Capture a snapshot of the global registry.
pub fn snapshot() -> MetricsSnapshot {
    let reg = registry();
    let counters = reg
        .counters
        .lock()
        .unwrap()
        .iter()
        .map(|((n, l), c)| CounterSample {
            name: n.clone(),
            labels: l.clone(),
            value: c.value(),
        })
        .collect();
    let gauges = reg
        .gauges
        .lock()
        .unwrap()
        .iter()
        .map(|((n, l), g)| GaugeSample {
            name: n.clone(),
            labels: l.clone(),
            value: g.value(),
            peak: g.peak(),
        })
        .collect();
    let hists = reg
        .hists
        .lock()
        .unwrap()
        .iter()
        .map(|((n, l), h)| HistSample {
            name: n.clone(),
            labels: l.clone(),
            data: h.merged(),
        })
        .collect();
    MetricsSnapshot {
        uptime: reg.uptime(),
        counters,
        gauges,
        hists,
        spans: recent_spans(),
    }
}

fn series_of(name: &str, labels: &str) -> String {
    if labels.is_empty() {
        name.to_string()
    } else {
        format!("{name}{{{labels}}}")
    }
}

fn merge_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("le=\"{le}\"")
    } else {
        format!("{labels},le=\"{le}\"")
    }
}

impl MetricsSnapshot {
    /// Value of the counter whose rendered series equals `series`
    /// (build the key with [`super::series`]).
    pub fn counter(&self, series: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|c| series_of(&c.name, &c.labels) == series)
            .map(|c| c.value)
    }

    /// Value of the gauge whose rendered series equals `series`.
    pub fn gauge(&self, series: &str) -> Option<i64> {
        self.gauges
            .iter()
            .find(|g| series_of(&g.name, &g.labels) == series)
            .map(|g| g.value)
    }

    /// High-water mark of the gauge whose rendered series equals
    /// `series`.
    pub fn gauge_peak(&self, series: &str) -> Option<i64> {
        self.gauges
            .iter()
            .find(|g| series_of(&g.name, &g.labels) == series)
            .map(|g| g.peak)
    }

    /// Merged data of the histogram whose rendered series equals
    /// `series`.
    pub fn hist(&self, series: &str) -> Option<&HistData> {
        self.hists
            .iter()
            .find(|h| series_of(&h.name, &h.labels) == series)
            .map(|h| &h.data)
    }

    /// Render the Prometheus text exposition format (version 0.0.4).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut last = String::new();
        for c in &self.counters {
            if c.name != last {
                let _ = writeln!(out, "# TYPE {} counter", c.name);
                last.clone_from(&c.name);
            }
            let _ = writeln!(out, "{} {}", series_of(&c.name, &c.labels), c.value);
        }
        last.clear();
        for g in &self.gauges {
            if g.name != last {
                let _ = writeln!(out, "# TYPE {} gauge", g.name);
                last.clone_from(&g.name);
            }
            let _ = writeln!(out, "{} {}", series_of(&g.name, &g.labels), g.value);
        }
        last.clear();
        for g in &self.gauges {
            let peak_name = format!("{}_peak", g.name);
            if peak_name != last {
                let _ = writeln!(out, "# TYPE {peak_name} gauge");
                last.clone_from(&peak_name);
            }
            let _ = writeln!(out, "{} {}", series_of(&peak_name, &g.labels), g.peak);
        }
        last.clear();
        for h in &self.hists {
            if h.name != last {
                let _ = writeln!(out, "# TYPE {} histogram", h.name);
                last.clone_from(&h.name);
            }
            let mut cum = 0u64;
            for (b, &c) in h.data.buckets.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                cum += c;
                // `le` is inclusive; our buckets hold integer values
                // strictly below the exclusive upper bound, so `hi - 1`
                // is the exact inclusive bound.
                let le = bucket_bounds(b).1 - 1;
                let _ = writeln!(
                    out,
                    "{}_bucket{{{}}} {}",
                    h.name,
                    merge_le(&h.labels, &le.to_string()),
                    cum
                );
            }
            let _ = writeln!(
                out,
                "{}_bucket{{{}}} {}",
                h.name,
                merge_le(&h.labels, "+Inf"),
                h.data.count
            );
            let sum_name = format!("{}_sum", h.name);
            let _ = writeln!(out, "{} {}", series_of(&sum_name, &h.labels), h.data.sum);
            let count_name = format!("{}_count", h.name);
            let _ = writeln!(
                out,
                "{} {}",
                series_of(&count_name, &h.labels),
                h.data.count
            );
        }
        out
    }

    /// Render as a JSON object: `uptime_s`, `counters`, `gauges`
    /// (value + peak), `histograms` (count/sum/mean/p50/p90/p99), and
    /// `spans`.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|c| (series_of(&c.name, &c.labels), Json::Num(c.value as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|g| {
                    (
                        series_of(&g.name, &g.labels),
                        Json::obj(vec![
                            ("value", Json::Num(g.value as f64)),
                            ("peak", Json::Num(g.peak as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|h| {
                    (
                        series_of(&h.name, &h.labels),
                        Json::obj(vec![
                            ("count", Json::Num(h.data.count as f64)),
                            ("sum", Json::Num(h.data.sum as f64)),
                            ("mean", Json::Num(h.data.mean())),
                            ("p50", Json::Num(h.data.percentile(50.0))),
                            ("p90", Json::Num(h.data.percentile(90.0))),
                            ("p99", Json::Num(h.data.percentile(99.0))),
                        ]),
                    )
                })
                .collect(),
        );
        let spans = Json::arr(
            self.spans
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("name", Json::str(s.name)),
                        ("depth", Json::Num(s.depth as f64)),
                        ("start_ns", Json::Num(s.start_ns as f64)),
                        ("dur_ns", Json::Num(s.dur_ns as f64)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("uptime_s", Json::num(self.uptime.as_secs_f64())),
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
            ("spans", spans),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn prometheus_rendering_has_type_lines_and_escaped_labels() {
        let _g = obs::test_guard();
        obs::set_enabled(true);
        obs::counter_with("obs_expose_test_total", &[("path", "a\\b\"c\nd")]).add(3);
        obs::gauge("obs_expose_test_gauge").set(7);
        let h = obs::histogram("obs_expose_test_ns");
        h.record(5);
        h.record(900);
        let text = snapshot().to_prometheus();
        assert!(text.contains("# TYPE obs_expose_test_total counter"));
        assert!(text.contains("obs_expose_test_total{path=\"a\\\\b\\\"c\\nd\"} 3"));
        assert!(text.contains("# TYPE obs_expose_test_gauge gauge"));
        assert!(text.contains("obs_expose_test_gauge 7"));
        assert!(text.contains("obs_expose_test_gauge_peak 7"));
        assert!(text.contains("# TYPE obs_expose_test_ns histogram"));
        assert!(text.contains("obs_expose_test_ns_bucket{le=\"5\"} 1"));
        assert!(text.contains("obs_expose_test_ns_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("obs_expose_test_ns_sum 905"));
        assert!(text.contains("obs_expose_test_ns_count 2"));
    }

    #[test]
    fn json_rendering_round_trips_through_the_parser() {
        let _g = obs::test_guard();
        obs::set_enabled(true);
        obs::counter("obs_expose_json_total").add(11);
        let json = snapshot().to_json();
        let parsed = Json::parse(&json.to_string_pretty()).expect("valid JSON");
        let v = parsed
            .req("counters")
            .unwrap()
            .req("obs_expose_json_total")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(v >= 11.0, "snapshot carries the recorded counter, got {v}");
    }

    #[test]
    fn snapshot_lookup_by_series_name() {
        let _g = obs::test_guard();
        obs::set_enabled(true);
        obs::counter_with("obs_expose_lookup_total", &[("k", "v")]).inc();
        let snap = snapshot();
        let key = obs::series("obs_expose_lookup_total", &[("k", "v")]);
        assert_eq!(snap.counter(&key), Some(1));
        assert_eq!(snap.counter("obs_expose_lookup_total"), None);
    }
}
