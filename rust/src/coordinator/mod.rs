//! L3 coordinator: the pipeline that turns an FP checkpoint into packed
//! quantized artifacts and evaluation reports — the paper's workflow
//! (§3–§4) as a reproducible, instrumented program.
//!
//! Stages (each timed, reported via [`crate::util::timer::Profiler`]):
//!
//! ```text
//!   load ─→ (outlier-amplify)? ─→ preprocess+quantize (per arm)
//!        ─→ pack+export (SQTZ) ─→ evaluate (CPU ref / PJRT) ─→ report
//! ```
//!
//! The multi-arm sweep (Table 1) fans out across the worker pool; each
//! arm is independent (pure function of the FP checkpoint).

pub mod server;

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::data::McqProblem;
use crate::eval::EvalReport;
use crate::kernels::KernelImpl;
use crate::io::{checkpoint::load_checkpoint, qmodel::save_qmodel};
use crate::model::quantized::{Method, QuantizedModel};
use crate::model::Checkpoint;
use crate::quant::Bits;
use crate::runtime::{scoring, Engine, EngineKind};
use crate::split::SplitConfig;
use crate::util::pool::Pool;
use crate::util::timer::Profiler;
use crate::{log_debug, log_error, log_info};

use anyhow::{Context, Result};

/// One arm of the experiment grid.
#[derive(Clone, Debug)]
pub struct Arm {
    pub bits: Bits,
    pub method: Method,
}

impl Arm {
    pub fn label(&self) -> String {
        format!("{}/{}", self.bits.name(), self.method.name())
    }
}

/// Result of quantizing + evaluating one arm.
#[derive(Clone, Debug)]
pub struct ArmResult {
    pub label: String,
    pub bits: Bits,
    pub method_name: String,
    pub quantize_time: Duration,
    pub packed_bytes: u64,
    pub report: EvalReport,
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineSpec {
    pub checkpoint: PathBuf,
    pub problems: PathBuf,
    pub out_dir: Option<PathBuf>,
    /// Outlier amplification applied to the FP model *before* all arms
    /// (DESIGN.md §3 substitution): (fraction, gain).
    pub amplify: Option<(f64, f32)>,
    /// Score through PJRT (`score_quant_k*` / `score_fp`) instead of the
    /// CPU reference forward.
    pub use_runtime: bool,
    /// CPU execution engine for quantized arms (`--engine` on the CLI).
    pub engine: EngineKind,
    /// Packed-kernel inner loops (`--kernel-impl` on the CLI):
    /// `Auto` (default, SIMD where the host supports it, LUT
    /// otherwise), or an explicit `simd`/`lut`/`scalar` request.
    pub kernel_impl: KernelImpl,
    pub seed: u64,
}

impl PipelineSpec {
    pub fn new(checkpoint: impl Into<PathBuf>, problems: impl Into<PathBuf>) -> Self {
        Self {
            checkpoint: checkpoint.into(),
            problems: problems.into(),
            out_dir: None,
            amplify: Some((0.003, 4.0)),
            use_runtime: false,
            engine: EngineKind::Reference,
            kernel_impl: KernelImpl::default(),
            seed: 7,
        }
    }
}

/// The coordinator: owns the worker pool, the profiler and (lazily) the
/// PJRT engine.
pub struct Coordinator {
    pub pool: Pool,
    pub profiler: Profiler,
    engine: Option<Engine>,
}

impl Coordinator {
    pub fn new() -> Coordinator {
        Coordinator::with_threads(0)
    }

    /// Coordinator with an explicit worker count (0 = available
    /// parallelism) — the CLI's `--threads` flag lands here.
    pub fn with_threads(threads: usize) -> Coordinator {
        Coordinator {
            pool: if threads == 0 {
                Pool::new_auto()
            } else {
                Pool::new(threads)
            },
            profiler: Profiler::new(),
            engine: None,
        }
    }

    pub fn with_engine(artifacts_dir: impl AsRef<Path>, variants: Option<&[&str]>) -> Result<Self> {
        let mut c = Coordinator::new();
        c.attach_engine(artifacts_dir, variants)?;
        Ok(c)
    }

    /// Load + compile the PJRT engine onto an existing coordinator.
    pub fn attach_engine(
        &mut self,
        artifacts_dir: impl AsRef<Path>,
        variants: Option<&[&str]>,
    ) -> Result<()> {
        self.engine = Some(Engine::load(artifacts_dir, variants)?);
        Ok(())
    }

    pub fn engine(&self) -> Option<&Engine> {
        self.engine.as_ref()
    }

    /// Load + optionally perturb the FP checkpoint.
    pub fn load_model(&self, spec: &PipelineSpec) -> Result<Checkpoint> {
        let mut ck = self.profiler.section("load", || {
            load_checkpoint(&spec.checkpoint)
                .with_context(|| format!("loading {}", spec.checkpoint.display()))
        })?;
        if let Some((frac, gain)) = spec.amplify {
            let touched = self
                .profiler
                .section("amplify_outliers", || ck.amplify_outliers(frac, gain, spec.seed));
            log_info!("amplified {touched} outlier weights (frac={frac}, gain={gain})");
        }
        Ok(ck)
    }

    pub fn load_problems(&self, spec: &PipelineSpec) -> Result<Vec<McqProblem>> {
        let (problems, _vocab) = crate::data::load_problems(&spec.problems)?;
        log_info!("loaded {} problems", problems.len());
        Ok(problems)
    }

    /// Quantize one arm (timed) through the layer-pipeline engine on the
    /// coordinator's pool; per-stage totals land in the profiler.
    pub fn quantize_arm(&self, ck: &Checkpoint, arm: &Arm) -> Result<(QuantizedModel, Duration)> {
        let label = arm.label();
        let (res, dur) = crate::util::timer::time_it(|| {
            crate::pipeline::quantize_with_pool(&self.pool, ck, arm.bits, &arm.method)
        });
        let (qm, report) = res?;
        self.profiler.record(&format!("quantize[{label}]"), dur);
        let stages = report.stage_totals();
        self.profiler.record("pipeline[cluster]", stages.cluster);
        self.profiler.record("pipeline[quantize]", stages.quantize);
        log_debug!(
            "quantized {label} in {:?} on {} workers (cpu {:?})",
            dur,
            report.threads,
            report.cpu_time()
        );
        Ok((qm, dur))
    }

    /// Evaluate a quantized model: PJRT when requested & compatible,
    /// otherwise the selected CPU engine — `Packed` executes the
    /// bit-packed planes through `crate::kernels`; `Reference`
    /// dequantizes to an effective f32 checkpoint. `use_runtime` takes
    /// precedence over `engine` (the CLI rejects the `--runtime
    /// --engine packed` combination so the conflict never goes silent).
    pub fn evaluate_qm(
        &self,
        qm: &QuantizedModel,
        problems: &[McqProblem],
        use_runtime: bool,
        engine: EngineKind,
    ) -> Result<EvalReport> {
        self.evaluate_qm_impl(qm, problems, use_runtime, engine, KernelImpl::default())
    }

    /// [`Self::evaluate_qm`] with an explicit packed-kernel
    /// implementation (the packed engine's `--kernel-impl`; the
    /// reference engine never touches the packed kernels).
    pub fn evaluate_qm_impl(
        &self,
        qm: &QuantizedModel,
        problems: &[McqProblem],
        use_runtime: bool,
        engine: EngineKind,
        kernel_impl: KernelImpl,
    ) -> Result<EvalReport> {
        if use_runtime {
            if let Some(engine) = &self.engine {
                if scoring::is_int_plane_compatible(qm) {
                    let k = scoring::plane_count(qm);
                    let variant = if k <= 1 { "score_quant_k1" } else { "score_quant_k3" };
                    if engine.variant(variant).is_ok() {
                        let args = scoring::quant_args(qm, k.max(1))?;
                        return self.profiler.section(&format!("eval_pjrt[{variant}]"), || {
                            scoring::score_problems(engine, variant, &args, problems)
                        });
                    }
                }
                // Fall through to FP scoring of the effective checkpoint.
                if engine.variant("score_fp").is_ok() {
                    let eff = qm.effective_checkpoint();
                    let args = scoring::fp_args(&eff);
                    return self.profiler.section("eval_pjrt[score_fp]", || {
                        scoring::score_problems(engine, "score_fp", &args, problems)
                    });
                }
            }
        }
        if engine == EngineKind::Packed {
            let pm = self
                .profiler
                .section("pack_model", || crate::model::packed::PackedModel::from_qmodel(qm))?;
            return self.profiler.section("eval_packed", || {
                crate::eval::evaluate_packed_impl(&pm, problems, &self.pool, kernel_impl)
            });
        }
        let eff = qm.effective_checkpoint();
        self.profiler
            .section("eval_cpu", || crate::eval::evaluate(&eff, problems, &self.pool))
    }

    /// Evaluate an FP checkpoint.
    pub fn evaluate_fp(
        &self,
        ck: &Checkpoint,
        problems: &[McqProblem],
        use_runtime: bool,
    ) -> Result<EvalReport> {
        if use_runtime {
            if let Some(engine) = &self.engine {
                if engine.variant("score_fp").is_ok() {
                    let args = scoring::fp_args(ck);
                    return self.profiler.section("eval_pjrt[score_fp]", || {
                        scoring::score_problems(engine, "score_fp", &args, problems)
                    });
                }
            }
        }
        self.profiler
            .section("eval_cpu", || crate::eval::evaluate(ck, problems, &self.pool))
    }

    /// Run a full arm: quantize → (export) → evaluate.
    pub fn run_arm(
        &self,
        ck: &Checkpoint,
        arm: &Arm,
        problems: &[McqProblem],
        spec: &PipelineSpec,
    ) -> Result<ArmResult> {
        let (qm, quantize_time) = self.quantize_arm(ck, arm)?;
        if let Some(dir) = &spec.out_dir {
            let fname = format!(
                "{}_{}.sqtz",
                arm.bits.name().to_lowercase(),
                qm.method_name
                    .replace(['(', ')', '=', '≤', '.'], "_")
            );
            self.profiler
                .section("export", || save_qmodel(dir.join(fname), &qm))?;
        }
        let report =
            self.evaluate_qm_impl(&qm, problems, spec.use_runtime, spec.engine, spec.kernel_impl)?;
        if report.n_errors > 0 {
            log_error!(
                "arm {}: {} problem(s) failed to score (first: {}); accuracy covers the {} scored",
                arm.label(),
                report.n_errors,
                report.first_error.as_deref().unwrap_or("unknown"),
                report.n
            );
        }
        Ok(ArmResult {
            label: arm.label(),
            bits: arm.bits,
            method_name: qm.method_name.clone(),
            quantize_time,
            packed_bytes: qm.packed_bytes(),
            report,
        })
    }

    /// The Table-1 grid: Original + {INT8, INT4, INT2} × {baseline, SQv2}.
    pub fn table1_arms(split_cfg: &SplitConfig) -> Vec<Arm> {
        let mut arms = Vec::new();
        for bits in [Bits::Int8, Bits::Int4, Bits::Int2] {
            arms.push(Arm {
                bits,
                method: Method::Baseline,
            });
            arms.push(Arm {
                bits,
                method: Method::SplitQuant(split_cfg.clone()),
            });
        }
        arms
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_problems, FactWorld};
    use crate::model::PicoLlamaConfig;

    #[test]
    fn arms_grid_is_complete() {
        let arms = Coordinator::table1_arms(&SplitConfig::default());
        assert_eq!(arms.len(), 6);
        assert!(arms.iter().any(|a| a.label() == "INT4/splitquantv2(k=3)"));
        assert!(arms.iter().any(|a| a.label() == "INT2/baseline"));
    }

    #[test]
    fn run_arm_end_to_end_cpu() {
        // Miniature end-to-end: random ckpt + tiny problem set.
        let world = FactWorld::generate(16, 4, 8, 1);
        let mut cfg = PicoLlamaConfig::test();
        cfg.vocab = world.vocab_size();
        let ck = Checkpoint::random_init(&cfg, 2);
        let problems = generate_problems(&world, 12, 3);
        let coord = Coordinator::new();
        let spec = PipelineSpec {
            checkpoint: PathBuf::from("unused"),
            problems: PathBuf::from("unused"),
            out_dir: None,
            amplify: None,
            use_runtime: false,
            engine: EngineKind::Packed,
            kernel_impl: KernelImpl::default(),
            seed: 1,
        };
        let arm = Arm {
            bits: Bits::Int8,
            method: Method::SplitQuant(SplitConfig::default()),
        };
        let res = coord.run_arm(&ck, &arm, &problems, &spec).unwrap();
        assert_eq!(res.report.n, 12);
        assert!(res.packed_bytes > 0);
        assert!(res.quantize_time.as_nanos() > 0);
        // The profiler recorded the stages.
        let report = coord.profiler.report();
        assert!(report.contains("quantize["), "{report}");
    }

    #[test]
    fn export_writes_files() {
        let world = FactWorld::generate(8, 3, 6, 1);
        let mut cfg = PicoLlamaConfig::test();
        cfg.vocab = world.vocab_size();
        let ck = Checkpoint::random_init(&cfg, 9);
        let problems = generate_problems(&world, 4, 3);
        let dir = std::env::temp_dir().join("sq_coord_export");
        std::fs::create_dir_all(&dir).unwrap();
        let coord = Coordinator::new();
        let spec = PipelineSpec {
            checkpoint: PathBuf::from("unused"),
            problems: PathBuf::from("unused"),
            out_dir: Some(dir.clone()),
            amplify: None,
            use_runtime: false,
            engine: EngineKind::Reference,
            kernel_impl: KernelImpl::default(),
            seed: 1,
        };
        let arm = Arm {
            bits: Bits::Int4,
            method: Method::Baseline,
        };
        coord.run_arm(&ck, &arm, &problems, &spec).unwrap();
        assert!(dir.join("int4_baseline.sqtz").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
