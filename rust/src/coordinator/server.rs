//! Batched inference server: the deployment-side driver (examples/
//! edge_deploy.rs) that serves MCQ scoring requests from a quantized
//! model with dynamic batching — the "edge AI device" role the paper
//! targets.
//!
//! Architecture (std threads; no tokio in the offline build):
//!
//! ```text
//!   clients ──(mpsc)──▶ batcher ──(collect ≤B, ≤max_wait)──▶ executor
//!                          ▲                                   │
//!                          └──────── responses (per-request oneshot)
//! ```
//!
//! The batcher groups pending requests up to the executor's batch size
//! or until `max_wait` expires — standard dynamic batching (the
//! vLLM-router pattern, scaled to this workload).
//!
//! Three execution backends ([`Backend`]):
//! * **Packed** — the packed-integer kernel engine
//!   ([`crate::model::packed::PackedModel`]): scores straight on the
//!   bit-packed planes, no PJRT artifacts or f32 weight dequants needed.
//! * **Reference** — the CPU reference forward over an effective
//!   (dequantized) f32 checkpoint.
//! * **Pjrt** — the AOT-compiled PJRT variants (requires `artifacts/`).

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::data::McqProblem;
use crate::eval::{nan_safe_argmax, ProblemResult};
use crate::kernels::KernelScratch;
use crate::model::forward::Workspace;
use crate::model::packed::PackedModel;
use crate::model::Checkpoint;
use crate::runtime::{ArgValue, Engine};

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// One scoring request.
pub struct Request {
    pub problem: McqProblem,
    /// Sender for the response.
    respond: mpsc::Sender<Result<Response>>,
    enqueued: Instant,
}

/// One scoring response with timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub result: ProblemResult,
    pub queue_time: Duration,
    pub batch_size: usize,
}

/// Server handle: submit requests, join on drop.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<thread::JoinHandle<()>>,
}

/// How the worker thread executes a batch.
pub enum Backend {
    /// AOT-compiled PJRT variants. The engine is constructed *inside*
    /// the worker thread (the xla client is not Send).
    Pjrt {
        artifacts_dir: PathBuf,
        weight_args: BTreeMap<String, ArgValue>,
    },
    /// Packed-integer kernel engine (CPU; no artifacts required).
    Packed(Box<PackedModel>),
    /// CPU reference forward over an effective f32 checkpoint.
    Reference(Box<Checkpoint>),
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// PJRT variant to execute (e.g. "score_quant_k3"); ignored by the
    /// CPU backends.
    pub variant: String,
    /// Batch size for the CPU backends (PJRT uses the compiled batch).
    pub max_batch: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(5),
            variant: "score_quant_k3".to_string(),
            max_batch: 16,
        }
    }
}

impl Server {
    /// Spawn the batcher/executor thread for a backend. Startup errors
    /// (e.g. PJRT compile failures) are returned synchronously through a
    /// handshake channel.
    pub fn start(backend: Backend, config: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = thread::spawn(move || {
            let mut exec = match backend {
                Backend::Pjrt {
                    artifacts_dir,
                    weight_args,
                } => match Engine::load(&artifacts_dir, Some(&[config.variant.as_str()])) {
                    Ok(engine) => Executor::Pjrt {
                        engine,
                        weight_args,
                    },
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                },
                // CPU backends hold one workspace + kernel scratch for
                // the thread's lifetime, sized to the model's max_seq
                // (validation rejects longer requests).
                Backend::Packed(pm) => {
                    let ws = Workspace::new(&pm.config, pm.config.max_seq);
                    Executor::Packed {
                        pm,
                        ws,
                        scratch: KernelScratch::new(),
                    }
                }
                Backend::Reference(ck) => {
                    let ws = Workspace::new(&ck.config, ck.config.max_seq);
                    Executor::Reference { ck, ws }
                }
            };
            let _ = ready_tx.send(Ok(()));
            batch_loop(&mut exec, &config, rx);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))??;
        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
        })
    }

    /// Submit a problem; returns a receiver for the response.
    pub fn submit(&self, problem: McqProblem) -> mpsc::Receiver<Result<Response>> {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            problem,
            respond: rtx,
            enqueued: Instant::now(),
        };
        if let Some(tx) = &self.tx {
            // A dropped batcher surfaces as a closed response channel.
            let _ = tx.send(req);
        }
        rrx
    }

    /// Submit synchronously.
    pub fn score(&self, problem: McqProblem) -> Result<Response> {
        self.submit(problem)
            .recv()
            .map_err(|_| anyhow!("server stopped"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue → batcher exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The worker-side executor (lives entirely on the batcher thread). The
/// CPU backends keep one workspace + kernel scratch alive for the whole
/// thread, so the serving hot path does no per-batch buffer allocation.
enum Executor {
    Pjrt {
        engine: Engine,
        weight_args: BTreeMap<String, ArgValue>,
    },
    Packed {
        pm: Box<PackedModel>,
        ws: Workspace,
        scratch: KernelScratch,
    },
    Reference {
        ck: Box<Checkpoint>,
        ws: Workspace,
    },
}

impl Executor {
    fn max_batch(&self, config: &ServerConfig) -> usize {
        match self {
            Executor::Pjrt { engine, .. } => engine.batch,
            _ => config.max_batch.max(1),
        }
    }

    /// Score a batch. The outer `Err` is a whole-batch failure (e.g. a
    /// PJRT execution error); the inner per-problem `Result`s carry
    /// request-level errors (a malformed problem fails alone — valid
    /// requests batched with it still succeed).
    fn score(
        &mut self,
        config: &ServerConfig,
        problems: &[McqProblem],
    ) -> Result<Vec<Result<ProblemResult>>> {
        match self {
            Executor::Pjrt {
                engine,
                weight_args,
            } => {
                // Per-problem prompt-length validation: a mismatched
                // request fails alone; the valid subset still executes.
                let plen = engine.prompt_len;
                let mut out: Vec<Option<Result<ProblemResult>>> = problems
                    .iter()
                    .map(|p| {
                        (p.prompt.len() != plen).then(|| {
                            Err(anyhow!(
                                "prompt length {} != the engine's compiled prompt_len \
                                 {plen}; this problem cannot be scored by variant '{}'",
                                p.prompt.len(),
                                config.variant
                            ))
                        })
                    })
                    .collect();
                let valid: Vec<McqProblem> = problems
                    .iter()
                    .zip(&out)
                    .filter(|(_, slot)| slot.is_none())
                    .map(|(p, _)| p.clone())
                    .collect();
                let mut scored =
                    per_problem_results(engine, weight_args, config, &valid)?.into_iter();
                Ok(out
                    .into_iter()
                    .map(|slot| {
                        slot.unwrap_or_else(|| Ok(scored.next().expect("one result per problem")))
                    })
                    .collect())
            }
            Executor::Packed { pm, ws, scratch } => Ok(problems
                .iter()
                .map(|p| {
                    validate_cpu_problem(&pm.config, p)?;
                    crate::eval::score_problem_packed(pm, p, ws, scratch)
                })
                .collect()),
            Executor::Reference { ck, ws } => Ok(problems
                .iter()
                .map(|p| {
                    validate_cpu_problem(&ck.config, p)?;
                    crate::eval::score_problem(ck, p, ws)
                })
                .collect()),
        }
    }
}

/// Reject a malformed request with an error instead of letting the
/// forward's asserts panic (and permanently kill) the batcher thread.
fn validate_cpu_problem(cfg: &crate::model::PicoLlamaConfig, p: &McqProblem) -> Result<()> {
    if p.prompt.is_empty() {
        bail!("problem has an empty prompt");
    }
    if p.options.is_empty() || p.options.iter().any(|o| o.is_empty()) {
        bail!("problem has empty options");
    }
    let max_opt = p.options.iter().map(|o| o.len()).max().unwrap_or(0);
    let seq = p.prompt.len() + max_opt;
    if seq > cfg.max_seq {
        bail!("sequence length {seq} exceeds the model's max_seq {}", cfg.max_seq);
    }
    if let Some(&t) = p
        .prompt
        .iter()
        .chain(p.options.iter().flatten())
        .find(|&&t| t >= cfg.vocab)
    {
        bail!("token {t} out of vocab {}", cfg.vocab);
    }
    Ok(())
}

fn batch_loop(exec: &mut Executor, config: &ServerConfig, rx: mpsc::Receiver<Request>) {
    let max_batch = exec.max_batch(config);
    loop {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + config.max_wait;
        // Fill greedily until the batch is full or the deadline passes.
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        execute_batch(exec, config, batch);
    }
}

fn execute_batch(exec: &mut Executor, config: &ServerConfig, batch: Vec<Request>) {
    let problems: Vec<McqProblem> = batch.iter().map(|r| r.problem.clone()).collect();
    let n = batch.len();
    match exec.score(config, &problems) {
        Ok(results) => {
            for (req, result) in batch.into_iter().zip(results) {
                let resp = result.map(|result| Response {
                    result,
                    queue_time: req.enqueued.elapsed(),
                    batch_size: n,
                });
                let _ = req.respond.send(resp);
            }
        }
        Err(e) => fail_all(batch, &e),
    }
}

fn fail_all(batch: Vec<Request>, e: &anyhow::Error) {
    for req in batch {
        let _ = req.respond.send(Err(anyhow!("batch failed: {e}")));
    }
}

/// Execute one PJRT batch and return per-problem results.
fn per_problem_results(
    engine: &Engine,
    weight_args: &BTreeMap<String, ArgValue>,
    config: &ServerConfig,
    problems: &[McqProblem],
) -> Result<Vec<ProblemResult>> {
    // score_problems pads internally; its report is aggregate only, so
    // inline the batching here for per-problem outputs.
    let b = engine.batch;
    let plen = engine.prompt_len;
    let mut results = Vec::with_capacity(problems.len());
    for chunk in problems.chunks(b) {
        let mut tokens = Vec::with_capacity(b * plen);
        for p in chunk {
            if p.prompt.len() != plen {
                bail!(
                    "prompt length {} != the engine's compiled prompt_len {plen}; \
                     this problem cannot be scored by variant '{}'",
                    p.prompt.len(),
                    config.variant
                );
            }
            tokens.extend(p.prompt.iter().map(|&t| t as i32));
        }
        // Pad the final chunk with neutral all-<pad> prompts of the
        // engine's prompt_len; the padding rows' logits are discarded.
        tokens.resize(b * plen, crate::data::PAD as i32);
        let mut args = (*weight_args).clone();
        args.insert("tokens".to_string(), ArgValue::I32(tokens));
        let logits = engine.execute(&config.variant, &args)?;
        for (i, p) in chunk.iter().enumerate() {
            let row = logits.row(i);
            let lps: Vec<f64> = p
                .options
                .iter()
                .map(|opt| crate::model::forward::log_prob(row, opt[0]))
                .collect();
            // NaN logprobs (a poisoned batch) must not panic the batch
            // thread: treat them as -inf and let the result surface.
            let chosen = nan_safe_argmax(&lps);
            results.push(ProblemResult {
                chosen,
                correct: p.correct,
                logprobs: lps,
            });
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    // Server tests that need real PJRT artifacts live in rust/tests/
    // integration; here we test the queueing scaffolding with the CPU
    // backends and the config defaults.
    use super::*;
    use crate::model::quantized::{quantize_model, Method};
    use crate::model::PicoLlamaConfig;
    use crate::quant::Bits;
    use crate::split::SplitConfig;

    #[test]
    fn config_defaults() {
        let c = ServerConfig::default();
        assert!(c.max_wait <= Duration::from_millis(50));
        assert!(c.variant.starts_with("score_"));
        assert!(c.max_batch >= 1);
    }

    fn setup() -> (crate::model::quantized::QuantizedModel, Vec<McqProblem>) {
        let world = crate::data::FactWorld::generate(16, 4, 8, 1);
        let mut cfg = PicoLlamaConfig::test();
        cfg.vocab = world.vocab_size();
        let ck = Checkpoint::random_init(&cfg, 3);
        let qm =
            quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let problems = crate::data::generate_problems(&world, 24, 3);
        (qm, problems)
    }

    #[test]
    fn packed_backend_serves_and_batches() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let server = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                max_wait: Duration::from_millis(20),
                max_batch: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let rx: Vec<_> = problems.iter().map(|p| server.submit(p.clone())).collect();
        let mut max_batch = 0;
        let mut n = 0;
        for r in rx {
            let resp = r.recv().unwrap().unwrap();
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
            max_batch = max_batch.max(resp.batch_size);
            n += 1;
        }
        assert_eq!(n, problems.len());
        assert!(max_batch > 1, "burst must batch");
    }

    #[test]
    fn malformed_request_errors_without_killing_the_server() {
        let (qm, problems) = setup();
        let server = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig::default(),
        )
        .unwrap();
        // Out-of-vocab token, empty prompt, over-long prompt: each must
        // come back as an error response, not a worker panic.
        let mut bad_vocab = problems[0].clone();
        bad_vocab.prompt[0] = 10_000;
        let mut empty_prompt = problems[0].clone();
        empty_prompt.prompt.clear();
        let mut too_long = problems[0].clone();
        too_long.prompt = vec![1; qm.config.max_seq + 1];
        for bad in [bad_vocab.clone(), empty_prompt, too_long] {
            assert!(server.score(bad).is_err());
        }
        // The server is still alive and scores valid problems.
        let ok = server.score(problems[0].clone()).unwrap();
        assert!(ok.result.logprobs.len() == problems[0].options.len());

        // A malformed request batched together with valid ones fails
        // alone; its batch-mates still succeed.
        let slow = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(200),
                max_batch: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_bad = slow.submit(bad_vocab);
        let rx_good = slow.submit(problems[1].clone());
        assert!(rx_bad.recv().unwrap().is_err());
        let good = rx_good.recv().unwrap().unwrap();
        assert!(good.result.logprobs.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn packed_and_reference_backends_agree() {
        let (qm, problems) = setup();
        let packed = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig::default(),
        )
        .unwrap();
        let reference = Server::start(
            Backend::Reference(Box::new(qm.effective_checkpoint())),
            ServerConfig::default(),
        )
        .unwrap();
        for p in &problems {
            let a = packed.score(p.clone()).unwrap();
            let b = reference.score(p.clone()).unwrap();
            // The engines agree on every decided problem; only a near-tie
            // on this untrained checkpoint may flip under FP reordering.
            if a.result.chosen != b.result.chosen {
                assert!(b.result.margin() < 1e-3, "margin {}", b.result.margin());
            }
        }
    }
}
