//! Serving front-end: MCQ scoring with dynamic batching **and**
//! continuous-batching streaming generation from one unified API — the
//! deployment-side driver (examples/edge_deploy.rs) for the "edge AI
//! device" role the paper targets.
//!
//! Architecture (std threads; no tokio in the offline build):
//!
//! ```text
//!   clients ──(mpsc Request)──▶ serve loop ──▶ executor
//!     ▲   ▲                        │  scoring: collect ≤B, ≤max_wait,
//!     │   │                        │           shard across worker pool
//!     │   └── TokenEvent stream ◀──┤  generation: one decode step per
//!     └────── ScoreResponse ◀──────┘      live session per iteration,
//!                                         admission at every step
//! ```
//!
//! **Scoring** keeps the original dynamic-batching behavior: requests
//! group up to the executor's batch size or until `max_wait` expires,
//! then the CPU executors shard the batch across a worker pool
//! (per-worker [`ScoreBuffers`], shared prompt-prefix LRU
//! [`PrefixCache`]) and score with prefix reuse.
//!
//! **Generation** is continuously batched (the vLLM pattern, scaled to
//! this workload): every live session holds a *paged*
//! [`DecodeState`] renting fixed-size K/V blocks from one shared
//! [`KvArena`], the serve loop runs one decode step across all live
//! sessions per iteration (sharded over the same worker pool), and new
//! requests are admitted between *steps* — not between completed
//! generations. Per-session decode replays [`generate_greedy_ops`]'s
//! exact call sequence (one prompt pass, then single-position extends),
//! and paged K/V reads are row-identical to the contiguous backing, so
//! continuous-batched output is **bit-identical** to sequential greedy
//! decoding (property-tested in `rust/tests/serving_stream.rs`).
//!
//! Overload handling is explicit and typed ([`ServeError`]):
//! * a bounded admission queue (`queue_cap`) sheds with `Overloaded`
//!   *synchronously* at submit time;
//! * sessions reserve their worst-case block count at admission —
//!   a request that can *never* fit the arena sheds with `KvExhausted`,
//!   one that is temporarily starved waits in a FIFO backlog;
//! * deadlines are enforced while queued and between decode steps
//!   (`DeadlineExceeded`), never by hanging;
//! * a dropped [`TokenStream`] cancels its session at the next step and
//!   returns its K/V blocks to the arena.
//!
//! Three execution backends ([`Backend`], constructed uniformly from an
//! [`EngineKind`] via [`Backend::from_kind`]):
//! * **Packed** — the packed-integer kernel engine
//!   ([`crate::model::packed::PackedModel`]): scores straight on the
//!   bit-packed planes, no PJRT artifacts or f32 weight dequants needed.
//! * **Reference** — the CPU reference forward over an effective
//!   (dequantized) f32 checkpoint.
//! * **Pjrt** — the AOT-compiled PJRT variants (requires `artifacts/`);
//!   scoring only — generation requests shed with `Unsupported`.
//!
//! [`generate_greedy_ops`]: crate::model::forward::generate_greedy
//! [`ScoreBuffers`]: crate::eval::ScoreBuffers
//! [`PrefixCache`]: crate::model::decode::PrefixCache

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::data::McqProblem;
use crate::eval::{self, nan_safe_argmax, PhaseTimes, ProblemResult, ScoreBuffers};
use crate::kernels::{KernelImpl, KernelScratch};
use crate::model::decode::{DecodeState, KvArena, PrefixCache};
use crate::model::forward::{self, CkOps, ForwardOps, Workspace};
use crate::model::packed::PackedModel;
use crate::model::specdec::{self, SpecConfig, SpecSession};
use crate::model::quantized::QuantizedModel;
use crate::model::{Checkpoint, PicoLlamaConfig};
use crate::obs;
use crate::runtime::{ArgValue, Engine, EngineKind};
use crate::util::failpoint::{self, sites as fp};
use crate::util::pool::{thread_budget, Pool};

use anyhow::{anyhow, bail, Result};

/// Typed serving failures. Carried through `anyhow::Error` so callers
/// can `downcast_ref::<ServeError>()` on any error path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline passed before it completed (while queued
    /// or between decode steps).
    DeadlineExceeded,
    /// The bounded admission queue is full; the request was shed at
    /// submit time.
    Overloaded,
    /// The request's worst-case K/V footprint exceeds the arena's total
    /// capacity — it can never be admitted.
    KvExhausted,
    /// The backend cannot serve this request kind (PJRT generation).
    Unsupported(String),
    /// The request failed validation (empty prompt, out-of-vocab token).
    Invalid(String),
    /// An engine error, contained worker panic, or watchdog
    /// cancellation surfaced mid-request.
    Internal(String),
    /// The server is draining: admissions are closed, and live sessions
    /// past the drain deadline are cancelled with this error.
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServeError::Overloaded => write!(f, "server overloaded: admission queue full"),
            ServeError::KvExhausted => write!(f, "kv arena too small for this request"),
            ServeError::Unsupported(what) => write!(f, "unsupported request: {what}"),
            ServeError::Invalid(why) => write!(f, "invalid request: {why}"),
            ServeError::Internal(why) => write!(f, "generation failed: {why}"),
            ServeError::ShuttingDown => write!(f, "server shutting down: admissions closed"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Handles into the global metrics registry for every serving-level
/// series (DESIGN.md §10). Resolved once behind a `OnceLock` so the hot
/// paths touch pre-looked-up handles; each handle gates its recording
/// on [`obs::enabled`], so everything here is near-free when telemetry
/// is off. Global rather than per-[`Server`] because client-side sheds
/// ([`Server::submit_generate`]'s `Overloaded` fast path) happen off
/// the serve-loop thread.
struct ServeMetrics {
    queue_depth: obs::Gauge,
    sessions_active: obs::Gauge,
    admissions: obs::Counter,
    score_requests: obs::Counter,
    shed_overloaded: obs::Counter,
    shed_deadline: obs::Counter,
    shed_kv: obs::Counter,
    shed_unsupported: obs::Counter,
    shed_invalid: obs::Counter,
    shed_internal: obs::Counter,
    shed_shutting_down: obs::Counter,
    panics: obs::Counter,
    watchdog_cancellations: obs::Counter,
    ttft_ns: obs::Histogram,
    latency_ns: obs::Histogram,
    tokens: obs::Counter,
}

fn serve_metrics() -> &'static ServeMetrics {
    static M: OnceLock<ServeMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let shed = |reason| obs::counter_with(obs::names::SERVE_SHED_TOTAL, &[("reason", reason)]);
        ServeMetrics {
            queue_depth: obs::gauge(obs::names::SERVE_QUEUE_DEPTH),
            sessions_active: obs::gauge(obs::names::SERVE_SESSIONS_ACTIVE),
            admissions: obs::counter(obs::names::SERVE_ADMISSIONS_TOTAL),
            score_requests: obs::counter(obs::names::SERVE_SCORE_REQUESTS_TOTAL),
            shed_overloaded: shed("overloaded"),
            shed_deadline: shed("deadline"),
            shed_kv: shed("kv_exhausted"),
            shed_unsupported: shed("unsupported"),
            shed_invalid: shed("invalid"),
            shed_internal: shed("internal"),
            shed_shutting_down: shed("shutting_down"),
            panics: obs::counter(obs::names::SERVER_PANICS_TOTAL),
            watchdog_cancellations: obs::counter(obs::names::WATCHDOG_CANCELLATIONS_TOTAL),
            ttft_ns: obs::histogram(obs::names::SERVE_TTFT_NS),
            latency_ns: obs::histogram(obs::names::SERVE_LATENCY_NS),
            tokens: obs::counter(obs::names::SERVE_TOKENS_TOTAL),
        }
    })
}

impl ServeMetrics {
    /// Bump the `reason`-labeled shed counter matching a typed serve
    /// error. Called at every site that emits one, so the labeled
    /// series sum to exactly the typed errors clients observe (pinned
    /// in `rust/tests/obs_metrics.rs`).
    fn shed(&self, e: &ServeError) {
        match e {
            ServeError::DeadlineExceeded => self.shed_deadline.inc(),
            ServeError::Overloaded => self.shed_overloaded.inc(),
            ServeError::KvExhausted => self.shed_kv.inc(),
            ServeError::Unsupported(_) => self.shed_unsupported.inc(),
            ServeError::Invalid(_) => self.shed_invalid.inc(),
            ServeError::Internal(_) => self.shed_internal.inc(),
            ServeError::ShuttingDown => self.shed_shutting_down.inc(),
        }
    }

    /// Record a completed request's TTFT and total latency.
    fn observe_timing(&self, t: &RequestTiming) {
        self.ttft_ns.record_duration(t.ttft());
        self.latency_ns.record_duration(t.total());
    }
}

/// Wall-clock phases of one served request. `queue` is enqueue →
/// admission into an executing batch/step; `prefill` is the prompt
/// pass (or prefix-cache restore); `decode` is everything after it
/// (option extensions for scoring, per-token steps for generation).
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestTiming {
    pub queue: Duration,
    pub prefill: Duration,
    pub decode: Duration,
}

impl RequestTiming {
    /// Time to first token: everything that precedes the first emitted
    /// token (queueing plus prefill) — the serving-latency headline.
    pub fn ttft(&self) -> Duration {
        self.queue + self.prefill
    }

    /// End-to-end latency.
    pub fn total(&self) -> Duration {
        self.queue + self.prefill + self.decode
    }
}

/// One scoring response with per-phase timing.
#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub result: ProblemResult,
    pub timing: RequestTiming,
    pub batch_size: usize,
}

impl ScoreResponse {
    /// End-to-end latency (queue + prefill + decode).
    pub fn latency(&self) -> Duration {
        self.timing.total()
    }
}

/// A streaming generation request: greedy-decode up to `max_tokens`
/// new tokens after `prompt`, optionally bounded by a deadline
/// (measured from submission; `None` falls back to the server's
/// `default_deadline`).
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: Vec<usize>,
    pub max_tokens: usize,
    pub deadline: Option<Duration>,
}

/// Why a generation stream completed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced the requested number of new tokens.
    MaxTokens,
    /// Hit the model's context limit.
    MaxSeq,
}

/// Terminal summary of one generation stream.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    /// All generated tokens, in order (the same tokens previously
    /// streamed as [`TokenEvent::Token`]).
    pub tokens: Vec<usize>,
    pub timing: RequestTiming,
    pub finish: FinishReason,
}

/// One event on a generation stream: zero or more `Token`s followed by
/// exactly one terminal `Done` or `Error`.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// The `index`-th generated token (0-based).
    Token { index: usize, token: usize },
    Done(GenerateResponse),
    Error(ServeError),
}

/// Receiving half of a generation stream. Dropping it cancels the
/// session: the serve loop notices at the next decode step and returns
/// the session's K/V blocks to the arena.
pub struct TokenStream {
    rx: mpsc::Receiver<TokenEvent>,
}

impl TokenStream {
    /// Next event, blocking; `None` once the stream is exhausted.
    pub fn recv(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Iterate events until the stream is exhausted.
    pub fn iter(&self) -> impl Iterator<Item = TokenEvent> + '_ {
        self.rx.iter()
    }

    /// Drain the stream to completion and return the terminal summary.
    /// [`ServeError`]s come back downcastable through `anyhow`.
    pub fn wait(self) -> Result<GenerateResponse> {
        for ev in self.rx.iter() {
            match ev {
                TokenEvent::Token { .. } => {}
                TokenEvent::Done(resp) => return Ok(resp),
                TokenEvent::Error(e) => return Err(e.into()),
            }
        }
        bail!("generation stream ended without a terminal event")
    }
}

/// One serving request — the wire type of the server's queue.
pub enum Request {
    /// MCQ scoring (the original serving workload).
    Score {
        problem: McqProblem,
        respond: mpsc::Sender<Result<ScoreResponse>>,
        enqueued: Instant,
        deadline: Option<Instant>,
    },
    /// Streaming greedy generation.
    Generate {
        spec: GenerateRequest,
        events: mpsc::Sender<TokenEvent>,
        enqueued: Instant,
        deadline: Option<Instant>,
    },
    /// Graceful drain ([`Server::drain`]): finish or deadline-cancel
    /// live sessions, shed everything still queued, then report.
    Drain {
        /// Absolute cutoff; sessions still live past it are cancelled
        /// with [`ServeError::ShuttingDown`]. `None` waits for all live
        /// sessions to finish naturally.
        deadline: Option<Instant>,
        respond: mpsc::Sender<DrainReport>,
    },
}

/// What [`Server::drain`] observed, measured on the serve-loop thread
/// after the last session released its blocks.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainReport {
    /// Sessions that finished naturally during the drain window.
    pub completed: usize,
    /// Sessions cancelled at the drain deadline (`ShuttingDown`).
    pub cancelled: usize,
    /// Queued/backlogged requests shed with `ShuttingDown`.
    pub shed: usize,
    /// Arena occupancy after the drain — 0 on a clean drain.
    pub kv_blocks_in_use: usize,
}

/// Server handle: submit scoring or generation requests, join on drop.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<thread::JoinHandle<()>>,
    /// The shared K/V block arena (CPU backends only) — exposed for
    /// occupancy introspection; the serve loop owns all mutation.
    arena: Option<Arc<KvArena>>,
    /// Generation requests submitted but not yet terminal — the bounded
    /// admission queue's synchronous backpressure counter.
    pending: Arc<AtomicUsize>,
    /// Set by [`Server::drain`]: submissions shed synchronously with
    /// [`ServeError::ShuttingDown`] without touching the queue.
    draining: Arc<std::sync::atomic::AtomicBool>,
    config: ServerConfig,
}

/// How the worker thread executes requests.
pub enum Backend {
    /// AOT-compiled PJRT variants. The engine is constructed *inside*
    /// the worker thread (the xla client is not Send). Scoring only.
    Pjrt {
        artifacts_dir: PathBuf,
        weight_args: BTreeMap<String, ArgValue>,
    },
    /// Packed-integer kernel engine (CPU; no artifacts required).
    Packed(Box<PackedModel>),
    /// CPU reference forward over an effective f32 checkpoint.
    Reference(Box<Checkpoint>),
}

impl Backend {
    /// Build the backend for an [`EngineKind`] from one quantized model
    /// — the single constructor the CLI and benches route through. The
    /// PJRT kind additionally needs the compiled artifacts directory;
    /// its weight args are derived for the default `score_quant_k3`
    /// variant.
    pub fn from_kind(
        kind: EngineKind,
        qm: &QuantizedModel,
        artifacts_dir: Option<&std::path::Path>,
    ) -> Result<Backend> {
        Ok(match kind {
            EngineKind::Packed => Backend::Packed(Box::new(PackedModel::from_qmodel(qm)?)),
            EngineKind::Reference => Backend::Reference(Box::new(qm.effective_checkpoint())),
            EngineKind::Pjrt => Backend::Pjrt {
                artifacts_dir: artifacts_dir
                    .ok_or_else(|| anyhow!("the pjrt backend needs an artifacts directory"))?
                    .to_path_buf(),
                weight_args: crate::runtime::scoring::quant_args(qm, 3)?,
            },
        })
    }

    fn model_config(&self) -> Option<&PicoLlamaConfig> {
        match self {
            Backend::Pjrt { .. } => None,
            Backend::Packed(pm) => Some(&pm.config),
            Backend::Reference(ck) => Some(&ck.config),
        }
    }
}

/// Server configuration. Prefer [`ServerConfig::builder`] — it rejects
/// inconsistent settings at construction instead of at serve time.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum time the batcher waits to fill a scoring batch.
    pub max_wait: Duration,
    /// PJRT variant to execute (e.g. "score_quant_k3"); ignored by the
    /// CPU backends.
    pub variant: String,
    /// Batch size for the CPU backends (PJRT uses the compiled batch).
    pub max_batch: usize,
    /// Worker threads a CPU executor shards a batch (or a decode step)
    /// across, each holding its own `ScoreBuffers`. 0 = available
    /// parallelism; PJRT ignores this.
    pub workers: usize,
    /// Prompt-prefix LRU capacity in entries (0 disables the cache).
    pub prefix_cache: usize,
    /// Score with prefix reuse (one prompt pass + per-option
    /// extensions). `false` falls back to the seed full-recompute path —
    /// kept as a benchmarking baseline (`perf_probe --serving-json`).
    pub reuse_prefix: bool,
    /// Packed-kernel inner loops (`--kernel-impl`): `Auto` (the
    /// default) resolves to the SIMD kernels on capable hosts and the
    /// LUT path otherwise; `Simd`/`Lut`/`Scalar` request a specific
    /// impl (see DESIGN.md §9). Resolution happens once per executor
    /// worker at startup. The reference backend ignores this.
    pub kernel_impl: KernelImpl,
    /// Threads each packed executor worker shards large GEMV output
    /// rows across (`--row-workers`). 0 = auto ([`thread_budget`]).
    pub row_workers: usize,
    /// Maximum concurrently *decoding* generation sessions; excess
    /// admitted requests wait in a FIFO backlog.
    pub max_sessions: usize,
    /// K/V positions per arena block (the paging granularity).
    pub kv_block_positions: usize,
    /// Total arena blocks. 0 = auto: enough for `max_sessions` sessions
    /// at the model's full context length.
    pub kv_blocks: usize,
    /// Bound on generation requests in flight (submitted, not yet
    /// terminal); beyond it `submit_generate` sheds with
    /// [`ServeError::Overloaded`] without enqueueing.
    pub queue_cap: usize,
    /// Deadline applied to requests that don't carry their own.
    pub default_deadline: Option<Duration>,
    /// Per-request token budget: `max_tokens` is clamped to this.
    pub max_new_tokens: usize,
    /// Speculative decoding: a low-bit draft model (same checkpoint,
    /// same geometry — [`specdec::check_draft_compat`]) that proposes
    /// tokens each decode step for the target backend to verify in one
    /// batched extend (`--speculative`; DESIGN.md §11). `None` decodes
    /// plainly. Output is bit-identical either way.
    pub draft: Option<Arc<PackedModel>>,
    /// Maximum draft tokens per speculative round (`--draft-k`);
    /// adapted downward per session when acceptance is poor. Ignored
    /// without a `draft`.
    pub draft_k: usize,
    /// Watchdog: cancel a session whose last decode step took longer
    /// than this budget (`--watchdog-ms`), releasing its blocks and
    /// shedding it as `internal`. `None` disables the watchdog.
    pub watchdog_step_budget: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(5),
            variant: "score_quant_k3".to_string(),
            max_batch: 16,
            workers: 1,
            prefix_cache: 32,
            reuse_prefix: true,
            kernel_impl: KernelImpl::default(),
            row_workers: 0,
            max_sessions: 64,
            kv_block_positions: 16,
            kv_blocks: 0,
            queue_cap: 1024,
            default_deadline: None,
            max_new_tokens: 256,
            draft: None,
            draft_k: 4,
            watchdog_step_budget: None,
        }
    }
}

impl ServerConfig {
    /// A validating builder starting from the defaults.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder {
            config: ServerConfig::default(),
        }
    }

    /// Reject inconsistent settings. Also enforced by [`Server::start`]
    /// for configs assembled by hand.
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("max_batch must be at least 1");
        }
        if self.max_sessions == 0 {
            bail!("max_sessions must be at least 1");
        }
        if self.kv_block_positions == 0 {
            bail!("kv_block_positions must be at least 1");
        }
        if self.queue_cap == 0 {
            bail!("queue_cap must be at least 1");
        }
        if self.max_new_tokens == 0 {
            bail!("max_new_tokens must be at least 1");
        }
        if self.draft.is_some() && self.draft_k == 0 {
            bail!("draft_k must be at least 1 when a draft model is configured");
        }
        if let Some(d) = self.default_deadline {
            if d < self.max_wait {
                bail!(
                    "default_deadline {d:?} is shorter than max_wait {:?}: \
                     every queued request would expire before the batcher fires",
                    self.max_wait
                );
            }
        }
        Ok(())
    }

    fn make_pool(&self) -> Pool {
        if self.workers == 0 {
            Pool::new_auto()
        } else {
            Pool::new(self.workers)
        }
    }

    /// The shared row pool packed executor workers attach to their
    /// kernel scratch, or `None` when the budget leaves no spare cores.
    fn make_row_pool(&self, batch_workers: usize) -> Option<Arc<Pool>> {
        let row = if self.row_workers > 0 {
            self.row_workers
        } else {
            let total = thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
            thread_budget(total, batch_workers).1
        };
        (row > 1).then(|| Arc::new(Pool::new(row)))
    }

    /// Arena size in blocks: explicit, or enough for `max_sessions`
    /// full-context sessions.
    fn arena_blocks(&self, cfg: &PicoLlamaConfig) -> usize {
        if self.kv_blocks > 0 {
            self.kv_blocks
        } else {
            self.max_sessions * cfg.max_seq.div_ceil(self.kv_block_positions)
        }
    }
}

/// Builder for [`ServerConfig`]; `build()` validates the combination.
#[derive(Clone, Debug)]
pub struct ServerConfigBuilder {
    config: ServerConfig,
}

impl ServerConfigBuilder {
    pub fn max_wait(mut self, v: Duration) -> Self {
        self.config.max_wait = v;
        self
    }
    pub fn variant(mut self, v: impl Into<String>) -> Self {
        self.config.variant = v.into();
        self
    }
    pub fn max_batch(mut self, v: usize) -> Self {
        self.config.max_batch = v;
        self
    }
    pub fn workers(mut self, v: usize) -> Self {
        self.config.workers = v;
        self
    }
    pub fn prefix_cache(mut self, v: usize) -> Self {
        self.config.prefix_cache = v;
        self
    }
    pub fn reuse_prefix(mut self, v: bool) -> Self {
        self.config.reuse_prefix = v;
        self
    }
    pub fn kernel_impl(mut self, v: KernelImpl) -> Self {
        self.config.kernel_impl = v;
        self
    }
    pub fn row_workers(mut self, v: usize) -> Self {
        self.config.row_workers = v;
        self
    }
    pub fn max_sessions(mut self, v: usize) -> Self {
        self.config.max_sessions = v;
        self
    }
    pub fn kv_block_positions(mut self, v: usize) -> Self {
        self.config.kv_block_positions = v;
        self
    }
    pub fn kv_blocks(mut self, v: usize) -> Self {
        self.config.kv_blocks = v;
        self
    }
    pub fn queue_cap(mut self, v: usize) -> Self {
        self.config.queue_cap = v;
        self
    }
    pub fn default_deadline(mut self, v: Option<Duration>) -> Self {
        self.config.default_deadline = v;
        self
    }
    pub fn max_new_tokens(mut self, v: usize) -> Self {
        self.config.max_new_tokens = v;
        self
    }
    pub fn draft(mut self, v: Option<Arc<PackedModel>>) -> Self {
        self.config.draft = v;
        self
    }
    pub fn draft_k(mut self, v: usize) -> Self {
        self.config.draft_k = v;
        self
    }
    pub fn watchdog_step_budget(mut self, v: Option<Duration>) -> Self {
        self.config.watchdog_step_budget = v;
        self
    }

    pub fn build(self) -> Result<ServerConfig> {
        self.config.validate()?;
        Ok(self.config)
    }
}

impl Server {
    /// Spawn the serve-loop thread for a backend. Startup errors (an
    /// invalid config, PJRT compile failures) are returned synchronously
    /// through a handshake channel.
    pub fn start(backend: Backend, config: ServerConfig) -> Result<Server> {
        config.validate()?;
        if let Some(draft) = &config.draft {
            let Some(cfg) = backend.model_config() else {
                bail!("speculative decoding needs a CPU backend (pjrt serves scoring only)");
            };
            specdec::check_draft_compat(&draft.config, cfg)?;
        }
        // The arena outlives the loop thread so the handle can report
        // occupancy; PJRT (scoring-only) serves without one.
        let arena = backend
            .model_config()
            .map(|cfg| Arc::new(KvArena::new(cfg, config.kv_block_positions, config.arena_blocks(cfg))));
        let pending = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let loop_arena = arena.clone();
        let loop_pending = Arc::clone(&pending);
        let loop_config = config.clone();
        let worker = thread::spawn(move || {
            let config = loop_config;
            let exec = match backend {
                Backend::Pjrt {
                    artifacts_dir,
                    weight_args,
                } => match Engine::load(&artifacts_dir, Some(&[config.variant.as_str()])) {
                    Ok(engine) => Executor::Pjrt {
                        engine,
                        weight_args,
                    },
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                },
                // CPU backends own a worker pool, a shared prefix cache
                // and one checkout slot of scoring buffers per worker,
                // all for the serve loop's lifetime — the serving hot
                // path does no per-batch buffer allocation.
                Backend::Packed(pm) => {
                    let pool = config.make_pool();
                    // Thread budget: cores beyond the batch-level pool
                    // go to intra-forward row sharding — a single
                    // decode stream then scales with cores instead of
                    // pinning one.
                    let row_pool = config.make_row_pool(pool.size());
                    let bufs = (0..pool.size())
                        .map(|_| {
                            let mut b = ScoreBuffers::for_packed(&pm, pm.config.max_seq);
                            b.scratch.set_kernel_impl(config.kernel_impl);
                            b.scratch.set_row_pool(row_pool.clone());
                            Mutex::new(b)
                        })
                        .collect();
                    let draft = DraftEngine::build(&config, pool.size(), row_pool.as_ref());
                    Executor::Packed {
                        pm,
                        pool,
                        cache: Mutex::new(PrefixCache::new(config.prefix_cache)),
                        bufs,
                        draft,
                    }
                }
                Backend::Reference(ck) => {
                    let pool = config.make_pool();
                    let bufs = (0..pool.size())
                        .map(|_| Mutex::new(ScoreBuffers::new(&ck.config, ck.config.max_seq)))
                        .collect();
                    let draft = DraftEngine::build(&config, pool.size(), None);
                    Executor::Reference {
                        ck,
                        pool,
                        cache: Mutex::new(PrefixCache::new(config.prefix_cache)),
                        bufs,
                        draft,
                    }
                }
            };
            let _ = ready_tx.send(Ok(()));
            serve_loop(&exec, &config, rx, &loop_pending, loop_arena.as_ref());
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))??;
        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
            arena,
            pending,
            draining: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            config,
        })
    }

    /// Submit a scoring problem; returns a receiver for the response.
    pub fn submit(&self, problem: McqProblem) -> mpsc::Receiver<Result<ScoreResponse>> {
        let (rtx, rrx) = mpsc::channel();
        if self.draining.load(Ordering::SeqCst) {
            serve_metrics().shed(&ServeError::ShuttingDown);
            let _ = rtx.send(Err(ServeError::ShuttingDown.into()));
            return rrx;
        }
        let req = Request::Score {
            problem,
            respond: rtx,
            enqueued: Instant::now(),
            deadline: self.config.default_deadline.map(|d| Instant::now() + d),
        };
        if let Some(tx) = &self.tx {
            // A dropped serve loop surfaces as a closed response channel.
            let _ = tx.send(req);
        }
        rrx
    }

    /// Score synchronously.
    pub fn score(&self, problem: McqProblem) -> Result<ScoreResponse> {
        self.submit(problem)
            .recv()
            .map_err(|_| anyhow!("server stopped"))?
    }

    /// Submit a generation request; returns the per-token event stream.
    /// Sheds synchronously with [`ServeError::Overloaded`] when more
    /// than `queue_cap` generation requests are already in flight.
    pub fn submit_generate(&self, spec: GenerateRequest) -> Result<TokenStream> {
        if self.draining.load(Ordering::SeqCst) {
            serve_metrics().shed(&ServeError::ShuttingDown);
            return Err(ServeError::ShuttingDown.into());
        }
        if self.pending.fetch_add(1, Ordering::SeqCst) >= self.config.queue_cap {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            serve_metrics().shed(&ServeError::Overloaded);
            return Err(ServeError::Overloaded.into());
        }
        let (etx, erx) = mpsc::channel();
        let enqueued = Instant::now();
        let deadline = spec
            .deadline
            .or(self.config.default_deadline)
            .map(|d| enqueued + d);
        let req = Request::Generate {
            spec,
            events: etx,
            enqueued,
            deadline,
        };
        match &self.tx {
            Some(tx) if tx.send(req).is_ok() => Ok(TokenStream { rx: erx }),
            _ => {
                self.pending.fetch_sub(1, Ordering::SeqCst);
                Err(anyhow!("server stopped"))
            }
        }
    }

    /// Generate synchronously: stream to completion, return the summary.
    pub fn generate(&self, prompt: &[usize], max_tokens: usize) -> Result<GenerateResponse> {
        self.submit_generate(GenerateRequest {
            prompt: prompt.to_vec(),
            max_tokens,
            deadline: None,
        })?
        .wait()
    }

    /// K/V arena blocks currently rented by live sessions (0 for PJRT,
    /// which has no arena). Lock-free read of the shared occupancy
    /// counter — safe to poll from any thread.
    pub fn kv_blocks_in_use(&self) -> usize {
        self.arena.as_ref().map_or(0, |a| a.blocks_in_use())
    }

    /// Gracefully drain the server: close admissions (every later
    /// submit sheds synchronously with [`ServeError::ShuttingDown`]),
    /// let live sessions finish — or cancel those still live once
    /// `deadline` elapses — shed everything queued, and return once the
    /// serve loop proves arena occupancy is back to 0.
    ///
    /// The server still answers occupancy queries afterwards and drops
    /// cleanly; it just refuses new work. Draining twice is idempotent
    /// (the second call reports an already-empty loop).
    pub fn drain(&self, deadline: Option<Duration>) -> Result<DrainReport> {
        self.draining.store(true, Ordering::SeqCst);
        let (rtx, rrx) = mpsc::channel();
        let req = Request::Drain {
            deadline: deadline.map(|d| Instant::now() + d),
            respond: rtx,
        };
        match &self.tx {
            Some(tx) if tx.send(req).is_ok() => {
                rrx.recv().map_err(|_| anyhow!("server stopped mid-drain"))
            }
            _ => Err(anyhow!("server stopped")),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue → the serve loop drains and exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The worker-side executor (lives entirely on the serve-loop thread).
/// The CPU backends shard scoring batches *and* generation decode steps
/// across their pool; every pool worker checks out one loop-lifetime
/// [`ScoreBuffers`] slot (workspace + decode state + prewarmed kernel
/// scratch, reused across batches and steps) and the workers share the
/// loop-lifetime prompt-prefix cache.
enum Executor {
    Pjrt {
        engine: Engine,
        weight_args: BTreeMap<String, ArgValue>,
    },
    Packed {
        pm: Box<PackedModel>,
        pool: Pool,
        cache: Mutex<PrefixCache>,
        bufs: Vec<Mutex<ScoreBuffers>>,
        draft: Option<DraftEngine>,
    },
    Reference {
        ck: Box<Checkpoint>,
        pool: Pool,
        cache: Mutex<PrefixCache>,
        bufs: Vec<Mutex<ScoreBuffers>>,
        draft: Option<DraftEngine>,
    },
}

/// The serve loop's shared draft engine for speculative decoding: the
/// low-bit packed model plus one loop-lifetime kernel scratch per pool
/// worker (checked out alongside the worker's [`ScoreBuffers`] slot by
/// the same ticket, so the speculative hot path allocates nothing per
/// step). Per-*session* speculative state (the draft's paged K/V, the
/// adaptive-`k` controller) lives in [`GenSession::spec`].
struct DraftEngine {
    pm: Arc<PackedModel>,
    k: usize,
    scratches: Vec<Mutex<KernelScratch>>,
}

impl DraftEngine {
    fn build(config: &ServerConfig, workers: usize, row_pool: Option<&Arc<Pool>>) -> Option<DraftEngine> {
        config.draft.as_ref().map(|pm| DraftEngine {
            pm: Arc::clone(pm),
            k: config.draft_k,
            scratches: (0..workers)
                .map(|_| {
                    let mut s = pm.prewarmed_scratch();
                    s.set_kernel_impl(config.kernel_impl);
                    s.set_row_pool(row_pool.cloned());
                    Mutex::new(s)
                })
                .collect(),
        })
    }
}

/// Shard one work list across the executor pool: every sweep worker
/// checks out a distinct long-lived buffer slot (the atomic ticket
/// makes indices unique and `workers <= bufs.len()` — the pool never
/// runs more workers than its size — so the lock never blocks) and
/// processes the items it claims through `work_one`. Shared by the
/// scoring batch path and the generation step path so the
/// sharding/checkout logic cannot drift between them.
fn shard_batch<T, R, F>(pool: &Pool, bufs: &[Mutex<ScoreBuffers>], items: &[T], work_one: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&mut ScoreBuffers, &T) -> R + Sync,
{
    let ticket = AtomicUsize::new(0);
    pool.parallel_map_init(
        items.len(),
        || {
            bufs[ticket.fetch_add(1, Ordering::Relaxed) % bufs.len()]
                .lock()
                .unwrap_or_else(|e| e.into_inner())
        },
        |guard, i| work_one(guard, &items[i]),
    )
}

/// Run one unit of worker work (one scored problem, one session step)
/// with panics contained to that unit: the payload becomes a typed
/// [`ServeError::Internal`] and bumps `server_panics_total`, while the
/// serve loop keeps serving every other request.
///
/// Unwind safety (DESIGN.md §12): the shared buffers a unit mutates —
/// `Workspace` activations, `KernelScratch`, paged `DecodeState`
/// appends — are write-before-read per forward call, and a state's
/// logical length advances only after its rows are fully written. A
/// half-finished unit therefore leaves buffers that the *next* unit
/// overwrites from scratch, and the panicked session itself is retired
/// (blocks released) by the serve loop, so `AssertUnwindSafe` is sound.
fn contained<R>(f: impl FnOnce() -> Result<R>) -> Result<R> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            serve_metrics().panics.inc();
            Err(ServeError::Internal(format!("worker panicked: {}", panic_message(&payload))).into())
        }
    }
}

/// Best-effort human-readable panic payload (`&str` / `String` covers
/// every `panic!` in this crate; anything else is labeled opaquely).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Executor {
    fn max_batch(&self, config: &ServerConfig) -> usize {
        match self {
            Executor::Pjrt { engine, .. } => engine.batch,
            _ => config.max_batch.max(1),
        }
    }

    /// The model config of the CPU backends; `None` for PJRT (which
    /// cannot serve generation).
    fn model_config(&self) -> Option<&PicoLlamaConfig> {
        match self {
            Executor::Pjrt { .. } => None,
            Executor::Packed { pm, .. } => Some(&pm.config),
            Executor::Reference { ck, .. } => Some(&ck.config),
        }
    }

    /// Score a batch. The outer `Err` is a whole-batch failure (e.g. a
    /// PJRT execution error); the inner per-problem `Result`s carry
    /// request-level errors (a malformed problem fails alone — valid
    /// requests batched with it still succeed). Each success carries its
    /// own prefill/decode wall-clock split.
    #[allow(clippy::type_complexity)]
    fn score(
        &self,
        config: &ServerConfig,
        problems: &[McqProblem],
    ) -> Result<Vec<Result<(ProblemResult, PhaseTimes)>>> {
        match self {
            Executor::Pjrt {
                engine,
                weight_args,
            } => {
                // Per-problem shape validation: a mismatched or
                // malformed request fails alone (instead of panicking
                // the serve loop); the valid subset still executes.
                let plen = engine.prompt_len;
                let mut out: Vec<Option<Result<(ProblemResult, PhaseTimes)>>> = problems
                    .iter()
                    .map(|p| {
                        if p.prompt.len() != plen {
                            Some(Err(anyhow!(
                                "prompt length {} != the engine's compiled prompt_len \
                                 {plen}; this problem cannot be scored by variant '{}'",
                                p.prompt.len(),
                                config.variant
                            )))
                        } else if p.options.is_empty() || p.options.iter().any(|o| o.is_empty()) {
                            Some(Err(anyhow!("problem has empty options")))
                        } else {
                            None
                        }
                    })
                    .collect();
                let valid: Vec<McqProblem> = problems
                    .iter()
                    .zip(&out)
                    .filter(|(_, slot)| slot.is_none())
                    .map(|(p, _)| p.clone())
                    .collect();
                let mut scored =
                    per_problem_results(engine, weight_args, config, &valid)?.into_iter();
                Ok(out
                    .into_iter()
                    .map(|slot| {
                        slot.unwrap_or_else(|| scored.next().expect("one result per problem"))
                    })
                    .collect())
            }
            Executor::Packed {
                pm,
                pool,
                cache,
                bufs,
                ..
            } => {
                let pm: &PackedModel = pm;
                let cache: &Mutex<PrefixCache> = cache;
                Ok(shard_batch(pool, bufs, problems, |bufs, p| {
                    contained(|| {
                        if let Some(msg) = failpoint::trigger(fp::WORKER_FORWARD) {
                            return Err(ServeError::Internal(msg).into());
                        }
                        eval::validate_problem(&pm.config, p)
                            .map_err(|e| ServeError::Invalid(e.to_string()))?;
                        if config.reuse_prefix {
                            let ScoreBuffers { ws, state, scratch } = bufs;
                            eval::score_problem_session_timed(
                                &mut pm.ops(scratch),
                                p,
                                ws,
                                state,
                                Some(cache),
                            )
                        } else {
                            // Full recompute with the real prefill/decode
                            // split: each option's prompt pass is prefill,
                            // its extension is decode. Logprobs stay
                            // bit-identical to the untimed oracle.
                            eval::score_problem_packed_full_timed(pm, p, bufs)
                        }
                    })
                }))
            }
            Executor::Reference {
                ck,
                pool,
                cache,
                bufs,
                ..
            } => {
                let ck: &Checkpoint = ck;
                let cache: &Mutex<PrefixCache> = cache;
                Ok(shard_batch(pool, bufs, problems, |bufs, p| {
                    contained(|| {
                        if let Some(msg) = failpoint::trigger(fp::WORKER_FORWARD) {
                            return Err(ServeError::Internal(msg).into());
                        }
                        eval::validate_problem(&ck.config, p)
                            .map_err(|e| ServeError::Invalid(e.to_string()))?;
                        if config.reuse_prefix {
                            let mut ops = CkOps::new(ck);
                            eval::score_problem_session_timed(
                                &mut ops,
                                p,
                                &mut bufs.ws,
                                &mut bufs.state,
                                Some(cache),
                            )
                        } else {
                            eval::score_problem_full_timed(ck, p, bufs)
                        }
                    })
                }))
            }
        }
    }

    /// One decode step for every live session, sharded across the pool
    /// exactly like a scoring batch. Each session advances by one token
    /// — or, with a draft engine configured, by one speculative round
    /// (≥ 1 token) — on its own paged state; token emission stays on
    /// the serve loop (the event `Sender` is not `Sync`).
    fn step_sessions(&self, sessions: &[Mutex<GenSession>]) -> Vec<Result<()>> {
        match self {
            Executor::Packed { pm, pool, bufs, draft, .. } => {
                let pm: &PackedModel = pm;
                match draft {
                    None => shard_batch(pool, bufs, sessions, |bufs, slot| {
                        let ScoreBuffers { ws, scratch, .. } = bufs;
                        let mut session = slot.lock().unwrap_or_else(|e| e.into_inner());
                        // Timed around the whole contained unit so the
                        // watchdog sees injected delays and panics too.
                        let t0 = Instant::now();
                        let r = contained(|| session.advance(&mut pm.ops(scratch), ws));
                        session.last_step = t0.elapsed();
                        r
                    }),
                    Some(d) => shard_batch_spec(pool, bufs, d, sessions, |bufs, ds, slot| {
                        let ScoreBuffers { ws, scratch, .. } = bufs;
                        let mut session = slot.lock().unwrap_or_else(|e| e.into_inner());
                        let t0 = Instant::now();
                        let r =
                            contained(|| session.advance_spec(&mut pm.ops(scratch), &d.pm, ds, ws));
                        session.last_step = t0.elapsed();
                        r
                    }),
                }
            }
            Executor::Reference { ck, pool, bufs, draft, .. } => {
                let ck: &Checkpoint = ck;
                match draft {
                    None => shard_batch(pool, bufs, sessions, |bufs, slot| {
                        let mut ops = CkOps::new(ck);
                        let mut session = slot.lock().unwrap_or_else(|e| e.into_inner());
                        let t0 = Instant::now();
                        let r = contained(|| session.advance(&mut ops, &mut bufs.ws));
                        session.last_step = t0.elapsed();
                        r
                    }),
                    Some(d) => shard_batch_spec(pool, bufs, d, sessions, |bufs, ds, slot| {
                        let mut ops = CkOps::new(ck);
                        let mut session = slot.lock().unwrap_or_else(|e| e.into_inner());
                        let t0 = Instant::now();
                        let r =
                            contained(|| session.advance_spec(&mut ops, &d.pm, ds, &mut bufs.ws));
                        session.last_step = t0.elapsed();
                        r
                    }),
                }
            }
            // Admission rejects every generation request on PJRT.
            Executor::Pjrt { .. } => unreachable!("pjrt sessions are rejected at admission"),
        }
    }
}

/// [`shard_batch`] with a second checkout: speculative decode steps
/// also need the worker's draft kernel scratch, claimed by the same
/// ticket so buffer slot `i` and draft scratch `i` always travel
/// together (both vectors are pool-sized, so neither lock blocks).
fn shard_batch_spec<T, R, F>(
    pool: &Pool,
    bufs: &[Mutex<ScoreBuffers>],
    draft: &DraftEngine,
    items: &[T],
    work_one: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&mut ScoreBuffers, &mut KernelScratch, &T) -> R + Sync,
{
    let ticket = AtomicUsize::new(0);
    pool.parallel_map_init(
        items.len(),
        || {
            let i = ticket.fetch_add(1, Ordering::Relaxed);
            (
                bufs[i % bufs.len()].lock().unwrap_or_else(|e| e.into_inner()),
                draft.scratches[i % draft.scratches.len()]
                    .lock()
                    .unwrap_or_else(|e| e.into_inner()),
            )
        },
        |(bufs, ds), i| work_one(bufs, ds, &items[i]),
    )
}

/// One live generation session. Its decode replays
/// `generate_greedy_ops`'s exact call sequence — one prompt pass, then
/// one single-position extend per token, greedy argmax between — on a
/// paged [`DecodeState`], which is what makes continuous-batched output
/// bit-identical to sequential greedy decoding. With a draft engine
/// configured the session instead steps by speculative rounds
/// ([`GenSession::advance_spec`]), whose greedy verification preserves
/// the same bit-identity guarantee.
struct GenSession {
    prompt: Vec<usize>,
    /// Effective budget (the request's `max_tokens` clamped to the
    /// server's `max_new_tokens`).
    max_tokens: usize,
    max_seq: usize,
    deadline: Option<Instant>,
    events: mpsc::Sender<TokenEvent>,
    state: DecodeState,
    tokens: Vec<usize>,
    queue: Duration,
    prefill: Duration,
    decode: Duration,
    prefilled: bool,
    /// Tokens already streamed to the client — trails `tokens.len()`
    /// by the latest step's emission count (speculative steps append
    /// several tokens at once).
    emitted: usize,
    /// Speculative per-session state (draft K/V + adaptive-`k`
    /// controller + acceptance stats); `None` decodes plainly.
    spec: Option<SpecSession>,
    /// Wall-clock of the previous decode step — the deadline-proximity
    /// signal that caps the draft length (a long speculative round is
    /// wasted work if the deadline expires mid-round).
    last_step: Duration,
}

impl GenSession {
    /// Advance by one token: prefill on the first call (the first token
    /// comes straight from the prompt logits), a single-position extend
    /// afterwards.
    fn advance<O: ForwardOps>(&mut self, ops: &mut O, ws: &mut Workspace) -> Result<()> {
        if let Some(msg) = failpoint::trigger(fp::WORKER_FORWARD) {
            return Err(ServeError::Internal(msg).into());
        }
        let row = if self.prefilled {
            let _span = crate::span!("decode_step");
            let t0 = Instant::now();
            let last = *self.tokens.last().expect("decode step before first token");
            let logits = forward::forward_extend(ops, &[last], self.state.len(), ws, &mut self.state)?;
            let row = logits.row(0).to_vec();
            self.decode += t0.elapsed();
            row
        } else {
            let _span = crate::span!("prefill");
            let t0 = Instant::now();
            let row = forward::prompt_pass(ops, &self.prompt, ws, &mut self.state)?;
            self.prefill = t0.elapsed();
            self.prefilled = true;
            row
        };
        self.tokens.push(forward::greedy_token(&row));
        Ok(())
    }

    /// Speculative advance: prefill behaves exactly like [`advance`]
    /// (plus resetting the draft state), then each decode step runs one
    /// [`specdec::spec_round`] — draft `m` tokens, verify them in one
    /// batched target extend, emit the accepted prefix + bonus token
    /// (≥ 1 token per step, bit-identical to plain decoding).
    ///
    /// `m` is the session's adaptive-`k` proposal, capped by the
    /// remaining budget (a round may emit `m + 1` tokens) and dropped
    /// to `0` — a pure target step — when the deadline is within two
    /// steps' wall-clock. Both caps change speed only, never output.
    ///
    /// [`advance`]: GenSession::advance
    fn advance_spec<O: ForwardOps>(
        &mut self,
        ops: &mut O,
        draft: &PackedModel,
        draft_scratch: &mut KernelScratch,
        ws: &mut Workspace,
    ) -> Result<()> {
        if let Some(msg) = failpoint::trigger(fp::WORKER_FORWARD) {
            return Err(ServeError::Internal(msg).into());
        }
        let spec = self.spec.as_mut().expect("speculative advance without a spec session");
        if !self.prefilled {
            let _span = crate::span!("prefill");
            let t0 = Instant::now();
            let row = forward::prompt_pass(ops, &self.prompt, ws, &mut self.state)?;
            spec.dstate.reset();
            self.prefill = t0.elapsed();
            self.prefilled = true;
            self.tokens.push(forward::greedy_token(&row));
            spec.stats.emitted += 1;
            return Ok(());
        }
        let _span = crate::span!("specdec_step");
        let t0 = Instant::now();
        // Same budget arithmetic as `generate_greedy_spec_ops`: a round
        // emits up to m + 1 tokens, so cap m one short of the remainder
        // (admission guarantees prompt.len() < max_seq, and the session
        // is retired before remaining hits 0).
        let total = self.max_tokens.min(self.max_seq - self.prompt.len());
        let remaining = total - self.tokens.len();
        let mut m = spec.ctrl.propose().min(remaining - 1);
        if let Some(d) = self.deadline {
            if d.saturating_duration_since(t0) < self.last_step * 2 {
                m = 0;
            }
        }
        let mut seq = Vec::with_capacity(self.prompt.len() + self.tokens.len());
        seq.extend_from_slice(&self.prompt);
        seq.extend_from_slice(&self.tokens);
        let out = specdec::spec_round(
            ops,
            draft,
            draft_scratch,
            &seq,
            m,
            ws,
            &mut self.state,
            &mut spec.dstate,
        )?;
        spec.ctrl.update(out.drafted, out.accepted);
        spec.stats.drafted += out.drafted as u64;
        spec.stats.accepted += out.accepted as u64;
        spec.stats.rounds += (out.drafted > 0) as u64;
        spec.stats.emitted += out.tokens.len() as u64;
        self.tokens.extend_from_slice(&out.tokens);
        self.last_step = t0.elapsed();
        self.decode += self.last_step;
        Ok(())
    }

    /// `Some` once the session has produced its last token (the same
    /// stop rule, in the same order, as `generate_greedy_ops`).
    fn finish_reason(&self) -> Option<FinishReason> {
        if self.tokens.len() >= self.max_tokens {
            Some(FinishReason::MaxTokens)
        } else if self.prompt.len() + self.tokens.len() >= self.max_seq {
            Some(FinishReason::MaxSeq)
        } else {
            None
        }
    }

    fn timing(&self) -> RequestTiming {
        RequestTiming {
            queue: self.queue,
            prefill: self.prefill,
            decode: self.decode,
        }
    }
}

/// A generation request waiting for admission.
struct GenJob {
    spec: GenerateRequest,
    events: mpsc::Sender<TokenEvent>,
    enqueued: Instant,
    deadline: Option<Instant>,
}

impl GenJob {
    /// Terminal error without admission; consumes the job.
    fn shed(self, e: ServeError, pending: &AtomicUsize) {
        serve_metrics().shed(&e);
        let _ = self.events.send(TokenEvent::Error(e));
        pending.fetch_sub(1, Ordering::SeqCst);
    }

    /// Terminal empty completion (zero-token requests); consumes the job.
    fn finish_empty(self, finish: FinishReason, pending: &AtomicUsize) {
        let _ = self.events.send(TokenEvent::Done(GenerateResponse {
            tokens: Vec::new(),
            timing: RequestTiming {
                queue: self.enqueued.elapsed(),
                ..RequestTiming::default()
            },
            finish,
        }));
        pending.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A scoring request waiting for a batch slot.
struct ScoreJob {
    problem: McqProblem,
    respond: mpsc::Sender<Result<ScoreResponse>>,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// The unified serve loop. With no generation in flight it behaves
/// exactly like the original dynamic batcher (block for the first
/// request, fill the scoring batch up to `max_wait`). With live
/// sessions it runs in *step mode*: each iteration drains the queue
/// without blocking (admission at every decode step — continuous
/// batching), executes any pending scoring batch, then advances every
/// live session by one token.
fn serve_loop(
    exec: &Executor,
    config: &ServerConfig,
    rx: mpsc::Receiver<Request>,
    pending: &AtomicUsize,
    arena: Option<&Arc<KvArena>>,
) {
    let max_batch = exec.max_batch(config);
    let mut sessions: Vec<Mutex<GenSession>> = Vec::new();
    let mut backlog: VecDeque<GenJob> = VecDeque::new();
    let mut closed = false;
    // Drain mode ([`Server::drain`]): queued work sheds with
    // `ShuttingDown`, admission closes, live sessions step to
    // completion (or are cancelled at the drain deadline), and the
    // report goes back once occupancy is provably 0.
    let mut drain: Option<DrainState> = None;
    loop {
        let mut scores: Vec<ScoreJob> = Vec::new();
        let mut fresh: Vec<GenJob> = Vec::new();
        let mut drains: Vec<(Option<Instant>, mpsc::Sender<DrainReport>)> = Vec::new();
        if sessions.is_empty() && backlog.is_empty() && drain.is_none() {
            if closed {
                return;
            }
            // Idle: block for the first request.
            match rx.recv() {
                Ok(r) => route(r, &mut scores, &mut fresh, &mut drains),
                Err(_) => return,
            }
            // Legacy dynamic batching: a lone scoring request waits up
            // to max_wait for batch-mates — but only while no
            // generation (or drain) work is pending.
            if fresh.is_empty() && !scores.is_empty() && drains.is_empty() {
                let deadline = Instant::now() + config.max_wait;
                while scores.len() < max_batch && fresh.is_empty() && drains.is_empty() {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => route(r, &mut scores, &mut fresh, &mut drains),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            closed = true;
                            break;
                        }
                    }
                }
            }
        } else {
            // Step mode: admit whatever is queued, without blocking.
            loop {
                match rx.try_recv() {
                    Ok(r) => route(r, &mut scores, &mut fresh, &mut drains),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            }
        }

        // Register drain requests. Concurrent drains merge: the
        // earliest deadline applies and every caller gets the report.
        for (deadline, respond) in drains {
            let d = drain.get_or_insert_with(DrainState::default);
            d.deadline = match (d.deadline, deadline) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            d.responders.push(respond);
        }

        if let Some(d) = &mut drain {
            // Admissions are closed: everything queued — scoring
            // requests, fresh generation requests, and the backlog —
            // sheds with the typed `ShuttingDown` reason.
            for job in scores.drain(..) {
                serve_metrics().shed(&ServeError::ShuttingDown);
                let _ = job.respond.send(Err(ServeError::ShuttingDown.into()));
                d.report.shed += 1;
            }
            for job in backlog.drain(..).chain(fresh) {
                job.shed(ServeError::ShuttingDown, pending);
                d.report.shed += 1;
            }
        } else {
            // Admission, FIFO: the backlog ahead of this iteration's
            // arrivals. Jobs that still don't fit (sessions full,
            // blocks temporarily rented out) go back to the backlog.
            let candidates = std::mem::take(&mut backlog);
            for job in candidates.into_iter().chain(fresh) {
                if let Some(waiting) = admit(job, exec, config, arena, &mut sessions, pending) {
                    backlog.push_back(waiting);
                }
            }
        }
        serve_metrics().queue_depth.set(backlog.len() as i64);

        // Scoring: execute everything drained, in batch-sized chunks.
        while !scores.is_empty() {
            let take = scores.len().min(max_batch);
            let chunk: Vec<ScoreJob> = scores.drain(..take).collect();
            execute_score_batch(exec, config, chunk);
        }

        // One decode step across all live sessions.
        shed_expired(&mut sessions, pending);
        let before = sessions.len();
        if !sessions.is_empty() {
            let results = exec.step_sessions(&sessions);
            retire_and_emit(&mut sessions, results, pending);
        }
        // Completed = retired by the step itself; watchdog cancellations
        // below are not completions and must not inflate the count.
        let completed_this_step = before - sessions.len();
        if let Some(budget) = config.watchdog_step_budget {
            watchdog_cancel(&mut sessions, budget, pending);
        }

        if let Some(d) = &mut drain {
            d.report.completed += completed_this_step;
            if !sessions.is_empty() && d.deadline.is_some_and(|dl| Instant::now() >= dl) {
                // Drain deadline: cancel every session still live,
                // releasing its blocks before the terminal event.
                for slot in sessions.drain(..) {
                    let s = slot.into_inner().unwrap_or_else(|e| e.into_inner());
                    serve_metrics().shed(&ServeError::ShuttingDown);
                    let GenSession { events, state, spec, .. } = s;
                    drop(state);
                    drop(spec);
                    let _ = events.send(TokenEvent::Error(ServeError::ShuttingDown));
                    pending.fetch_sub(1, Ordering::SeqCst);
                    d.report.cancelled += 1;
                }
            }
            if sessions.is_empty() {
                // Every session is terminal and every queued request is
                // shed: measure occupancy (exactly 0 unless something
                // outside the loop still rents blocks) and reply.
                let mut report = d.report;
                report.kv_blocks_in_use = arena.map_or(0, |a| a.blocks_in_use());
                for r in d.responders.drain(..) {
                    let _ = r.send(report);
                }
                drain = None;
            }
        }
        serve_metrics().sessions_active.set(sessions.len() as i64);
    }
}

/// Accumulated state of an in-progress drain.
#[derive(Default)]
struct DrainState {
    deadline: Option<Instant>,
    responders: Vec<mpsc::Sender<DrainReport>>,
    report: DrainReport,
}

fn route(
    req: Request,
    scores: &mut Vec<ScoreJob>,
    fresh: &mut Vec<GenJob>,
    drains: &mut Vec<(Option<Instant>, mpsc::Sender<DrainReport>)>,
) {
    match req {
        Request::Score {
            problem,
            respond,
            enqueued,
            deadline,
        } => scores.push(ScoreJob {
            problem,
            respond,
            enqueued,
            deadline,
        }),
        Request::Generate {
            spec,
            events,
            enqueued,
            deadline,
        } => fresh.push(GenJob {
            spec,
            events,
            enqueued,
            deadline,
        }),
        Request::Drain { deadline, respond } => drains.push((deadline, respond)),
    }
}

/// Cancel sessions whose last decode step blew the watchdog budget:
/// typed `Internal` error, blocks released before the terminal event,
/// neighbors untouched. Runs after retirement, so a session that
/// finished on its slow step still completes normally.
fn watchdog_cancel(sessions: &mut Vec<Mutex<GenSession>>, budget: Duration, pending: &AtomicUsize) {
    let mut keep = Vec::with_capacity(sessions.len());
    for slot in std::mem::take(sessions) {
        let s = slot.into_inner().unwrap_or_else(|e| e.into_inner());
        if s.last_step > budget {
            let m = serve_metrics();
            m.watchdog_cancellations.inc();
            let err = ServeError::Internal(format!(
                "watchdog: decode step took {:?} (budget {budget:?})",
                s.last_step
            ));
            m.shed(&err);
            let GenSession { events, state, spec, .. } = s;
            drop(state);
            drop(spec);
            let _ = events.send(TokenEvent::Error(err));
            pending.fetch_sub(1, Ordering::SeqCst);
        } else {
            keep.push(Mutex::new(s));
        }
    }
    *sessions = keep;
}

/// Try to admit one generation request. Terminal outcomes (validation
/// failure, expired deadline, impossible K/V footprint, zero-token
/// requests) are emitted here; `Some(job)` hands the request back for
/// the backlog (sessions full, or blocks temporarily rented out).
fn admit(
    job: GenJob,
    exec: &Executor,
    config: &ServerConfig,
    arena: Option<&Arc<KvArena>>,
    sessions: &mut Vec<Mutex<GenSession>>,
    pending: &AtomicUsize,
) -> Option<GenJob> {
    // Soft failpoint: this runs on the serve-loop thread, where a panic
    // would kill the scheduler for everyone — injected panics degrade
    // to a typed shed on this one request.
    if let Some(msg) = failpoint::trigger_soft(fp::SERVER_ADMIT) {
        job.shed(ServeError::Internal(msg), pending);
        return None;
    }
    if job.deadline.is_some_and(|d| Instant::now() >= d) {
        job.shed(ServeError::DeadlineExceeded, pending);
        return None;
    }
    let Some(cfg) = exec.model_config() else {
        job.shed(
            ServeError::Unsupported("the pjrt backend serves scoring only".into()),
            pending,
        );
        return None;
    };
    if job.spec.prompt.is_empty() {
        job.shed(ServeError::Invalid("empty prompt".into()), pending);
        return None;
    }
    if let Some(&t) = job.spec.prompt.iter().find(|&&t| t >= cfg.vocab) {
        job.shed(
            ServeError::Invalid(format!("token {t} out of vocab {}", cfg.vocab)),
            pending,
        );
        return None;
    }
    // Degenerate budgets complete immediately with zero tokens — the
    // same outcome as `generate_greedy_ops`'s early return.
    let max_tokens = job.spec.max_tokens.min(config.max_new_tokens);
    if job.spec.prompt.len() >= cfg.max_seq {
        job.finish_empty(FinishReason::MaxSeq, pending);
        return None;
    }
    if max_tokens == 0 {
        job.finish_empty(FinishReason::MaxTokens, pending);
        return None;
    }
    let arena = arena.expect("cpu backends always serve with an arena");
    // Conservative reservation: rent the worst-case block count now so
    // an admitted session can never hit arena exhaustion mid-decode.
    // A speculative session carries a second (draft) K/V state of the
    // same worst-case footprint, rented from the same arena.
    let need = (job.spec.prompt.len() + max_tokens).min(cfg.max_seq);
    let states = if config.draft.is_some() { 2 } else { 1 };
    if states * arena.blocks_for(need) > arena.total_blocks() {
        job.shed(ServeError::KvExhausted, pending);
        return None;
    }
    if sessions.len() >= config.max_sessions {
        return Some(job);
    }
    let mut state = DecodeState::paged(cfg, Arc::clone(arena));
    if state.reserve(need).is_err() {
        // Blocks are rented out to live sessions; dropping `state`
        // returns any partial rental. Retry as sessions retire.
        return Some(job);
    }
    let spec = match &config.draft {
        None => None,
        Some(_) => {
            let mut dstate = DecodeState::paged(cfg, Arc::clone(arena));
            if dstate.reserve(need).is_err() {
                // Same retry path; dropping both states returns the
                // target's rental too — admission is all-or-nothing.
                return Some(job);
            }
            Some(SpecSession::new(
                &SpecConfig { k: config.draft_k, adaptive: true },
                dstate,
            ))
        }
    };
    sessions.push(Mutex::new(GenSession {
        prompt: job.spec.prompt,
        max_tokens,
        max_seq: cfg.max_seq,
        deadline: job.deadline,
        events: job.events,
        state,
        tokens: Vec::with_capacity(max_tokens),
        queue: job.enqueued.elapsed(),
        prefill: Duration::ZERO,
        decode: Duration::ZERO,
        prefilled: false,
        emitted: 0,
        spec,
        last_step: Duration::ZERO,
    }));
    serve_metrics().admissions.inc();
    None
}

/// Retire sessions whose deadline passed between steps: typed error,
/// blocks returned, no hang.
fn shed_expired(sessions: &mut Vec<Mutex<GenSession>>, pending: &AtomicUsize) {
    let now = Instant::now();
    sessions.retain(|slot| {
        let s = slot.lock().unwrap_or_else(|e| e.into_inner());
        if s.deadline.is_some_and(|d| now >= d) {
            serve_metrics().shed(&ServeError::DeadlineExceeded);
            let _ = s.events.send(TokenEvent::Error(ServeError::DeadlineExceeded));
            pending.fetch_sub(1, Ordering::SeqCst);
            false // dropping the session frees its arena blocks
        } else {
            true
        }
    });
}

/// Emit this step's tokens for every session and retire the finished,
/// failed, and cancelled ones (a dropped [`TokenStream`] turns the
/// emit into a send error — that is the cancellation signal). A plain
/// step emits exactly one token; a speculative step emits every token
/// its round produced (accepted drafts + bonus), in order.
fn retire_and_emit(
    sessions: &mut Vec<Mutex<GenSession>>,
    results: Vec<Result<()>>,
    pending: &AtomicUsize,
) {
    let mut keep = Vec::with_capacity(sessions.len());
    for (slot, res) in std::mem::take(sessions).into_iter().zip(results) {
        let mut s = slot.into_inner().unwrap_or_else(|e| e.into_inner());
        // Soft failpoint on the serve-loop thread: an injected emit
        // failure retires this session with a typed internal error.
        let res = match res {
            Ok(()) => match failpoint::trigger_soft(fp::STREAM_EMIT) {
                Some(msg) => Err(ServeError::Internal(msg).into()),
                None => Ok(()),
            },
            err => err,
        };
        match res {
            Err(e) => {
                // Preserve the typed error when there is one (contained
                // panics arrive as `Internal` already) instead of
                // double-wrapping it.
                let err = e
                    .downcast_ref::<ServeError>()
                    .cloned()
                    .unwrap_or_else(|| ServeError::Internal(format!("{e:#}")));
                serve_metrics().shed(&err);
                // Blocks (target *and* draft) return to the arena
                // before the terminal event is visible — same contract
                // as the Done path below.
                let GenSession { events, state, spec, .. } = s;
                drop(state);
                drop(spec);
                let _ = events.send(TokenEvent::Error(err));
                pending.fetch_sub(1, Ordering::SeqCst);
            }
            Ok(()) => {
                let mut cancelled = false;
                for index in s.emitted..s.tokens.len() {
                    let token = s.tokens[index];
                    if s.events.send(TokenEvent::Token { index, token }).is_err() {
                        // Receiver dropped → cancelled; free the blocks
                        // now (any tokens left this step die with it).
                        cancelled = true;
                        break;
                    }
                }
                s.emitted = s.tokens.len();
                if cancelled {
                    pending.fetch_sub(1, Ordering::SeqCst);
                } else if let Some(finish) = s.finish_reason() {
                    let timing = s.timing();
                    let GenSession {
                        events,
                        tokens,
                        state,
                        spec,
                        ..
                    } = s;
                    let m = serve_metrics();
                    m.observe_timing(&timing);
                    m.tokens.add(tokens.len() as u64);
                    // Blocks (target *and* draft) return to the arena
                    // *before* Done is visible, so a client that
                    // observed the terminal event sees occupancy
                    // already released.
                    drop(state);
                    drop(spec);
                    let _ = events.send(TokenEvent::Done(GenerateResponse {
                        tokens,
                        timing,
                        finish,
                    }));
                    pending.fetch_sub(1, Ordering::SeqCst);
                } else {
                    keep.push(Mutex::new(s));
                }
            }
        }
    }
    *sessions = keep;
}

fn execute_score_batch(exec: &Executor, config: &ServerConfig, jobs: Vec<ScoreJob>) {
    let started = Instant::now();
    // Shed requests whose deadline passed while queued — typed, no hang.
    let mut live = Vec::with_capacity(jobs.len());
    for job in jobs {
        if job.deadline.is_some_and(|d| started >= d) {
            serve_metrics().shed(&ServeError::DeadlineExceeded);
            let _ = job.respond.send(Err(ServeError::DeadlineExceeded.into()));
        } else {
            serve_metrics().score_requests.inc();
            live.push(job);
        }
    }
    if live.is_empty() {
        return;
    }
    let problems: Vec<McqProblem> = live.iter().map(|j| j.problem.clone()).collect();
    let batch_size = live.len();
    match exec.score(config, &problems) {
        Ok(results) => {
            for (job, result) in live.into_iter().zip(results) {
                let resp = result
                    .map(|(result, phases)| ScoreResponse {
                        result,
                        timing: RequestTiming {
                            queue: started.duration_since(job.enqueued),
                            prefill: phases.prefill,
                            decode: phases.decode,
                        },
                        batch_size,
                    })
                    .map_err(|e| {
                        // Per-problem failures shed typed like every
                        // other error path, so the reason-labeled shed
                        // counters keep summing to exactly the errors
                        // clients observe.
                        let err = e
                            .downcast_ref::<ServeError>()
                            .cloned()
                            .unwrap_or_else(|| ServeError::Internal(format!("{e:#}")));
                        serve_metrics().shed(&err);
                        anyhow::Error::from(err)
                    });
                if let Ok(r) = &resp {
                    serve_metrics().observe_timing(&r.timing);
                }
                let _ = job.respond.send(resp);
            }
        }
        Err(e) => {
            for job in live {
                let err = ServeError::Internal(format!("batch failed: {e}"));
                serve_metrics().shed(&err);
                let _ = job.respond.send(Err(err.into()));
            }
        }
    }
}

/// Execute one PJRT batch and return per-problem results. Callers
/// ([`Executor::score`]) have already shape-validated every problem
/// (prompt length, non-empty options); token-range errors that only
/// surface against the executed logits (an out-of-vocab option) come
/// back as that problem's inner `Err`. The device executes the whole
/// padded batch in one call — that call *is* each member's prefill;
/// scoring reads the returned logits with no further decode.
#[allow(clippy::type_complexity)]
fn per_problem_results(
    engine: &Engine,
    weight_args: &BTreeMap<String, ArgValue>,
    config: &ServerConfig,
    problems: &[McqProblem],
) -> Result<Vec<Result<(ProblemResult, PhaseTimes)>>> {
    // score_problems pads internally; its report is aggregate only, so
    // inline the batching here for per-problem outputs.
    let b = engine.batch;
    let plen = engine.prompt_len;
    let mut results = Vec::with_capacity(problems.len());
    for chunk in problems.chunks(b) {
        let mut tokens = Vec::with_capacity(b * plen);
        for p in chunk {
            debug_assert_eq!(p.prompt.len(), plen, "caller pre-validates prompt length");
            tokens.extend(p.prompt.iter().map(|&t| t as i32));
        }
        // Pad the final chunk with neutral all-<pad> prompts of the
        // engine's prompt_len; the padding rows' logits are discarded.
        tokens.resize(b * plen, crate::data::PAD as i32);
        let mut args = (*weight_args).clone();
        args.insert("tokens".to_string(), ArgValue::I32(tokens));
        let exec_started = Instant::now();
        let logits = engine.execute(&config.variant, &args)?;
        let phases = PhaseTimes {
            prefill: exec_started.elapsed(),
            decode: Duration::ZERO,
        };
        let vocab = logits.shape()[1];
        for (i, p) in chunk.iter().enumerate() {
            let row = logits.row(i);
            let lps: Result<Vec<f64>> = p
                .options
                .iter()
                .map(|opt| {
                    if opt[0] >= vocab {
                        bail!("option token {} out of vocab {vocab}", opt[0]);
                    }
                    Ok(crate::model::forward::log_prob(row, opt[0]))
                })
                .collect();
            // NaN logprobs (a poisoned batch) must not panic the batch
            // thread: treat them as -inf and let the result surface.
            results.push(lps.map(|lps| {
                (
                    ProblemResult {
                        chosen: nan_safe_argmax(&lps),
                        correct: p.correct,
                        logprobs: lps,
                    },
                    phases,
                )
            }));
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    // Server tests that need real PJRT artifacts live in rust/tests/
    // integration; here we test the queueing scaffolding with the CPU
    // backends and the config defaults. The generation bit-identity and
    // overload-behavior suite lives in rust/tests/serving_stream.rs.
    use super::*;
    use crate::model::quantized::{quantize_model, Method};
    use crate::model::PicoLlamaConfig;
    use crate::quant::Bits;
    use crate::split::SplitConfig;

    #[test]
    fn config_defaults() {
        let c = ServerConfig::default();
        assert!(c.max_wait <= Duration::from_millis(50));
        assert!(c.variant.starts_with("score_"));
        assert!(c.max_batch >= 1);
        assert!(c.workers >= 1, "default avoids surprise thread fan-out");
        assert!(c.reuse_prefix, "prefix reuse is the default scoring path");
        assert!(c.max_sessions >= 1);
        assert!(c.kv_block_positions >= 1);
        assert_eq!(c.kv_blocks, 0, "arena auto-sizes by default");
        assert!(c.queue_cap >= c.max_sessions);
        assert!(c.max_new_tokens >= 1);
        c.validate().expect("defaults must validate");
    }

    #[test]
    fn builder_validates_config() {
        // The builder accepts a sensible combination...
        let c = ServerConfig::builder()
            .max_batch(4)
            .max_sessions(8)
            .kv_block_positions(8)
            .queue_cap(64)
            .default_deadline(Some(Duration::from_secs(1)))
            .build()
            .unwrap();
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.max_sessions, 8);
        // ...and rejects nonsense.
        assert!(ServerConfig::builder().max_batch(0).build().is_err());
        assert!(ServerConfig::builder().max_sessions(0).build().is_err());
        assert!(ServerConfig::builder().kv_block_positions(0).build().is_err());
        assert!(ServerConfig::builder().queue_cap(0).build().is_err());
        assert!(ServerConfig::builder().max_new_tokens(0).build().is_err());
        // A default deadline shorter than the batching window would
        // expire every queued request before the batcher fires.
        assert!(ServerConfig::builder()
            .max_wait(Duration::from_millis(50))
            .default_deadline(Some(Duration::from_millis(10)))
            .build()
            .is_err());
    }

    #[test]
    fn speculative_config_and_start_validation() {
        let (qm, _) = setup();
        let pm = Arc::new(PackedModel::from_qmodel(&qm).unwrap());
        // draft_k = 0 with a draft configured is rejected at build time.
        assert!(ServerConfig::builder()
            .draft(Some(Arc::clone(&pm)))
            .draft_k(0)
            .build()
            .is_err());
        // ...but draft_k is ignored without a draft.
        assert!(ServerConfig::builder().draft_k(0).build().is_ok());
        // PJRT serves scoring only; a draft model is rejected at start.
        let err = Server::start(
            Backend::Pjrt {
                artifacts_dir: PathBuf::from("/nonexistent"),
                weight_args: BTreeMap::new(),
            },
            ServerConfig {
                draft: Some(Arc::clone(&pm)),
                ..Default::default()
            },
        );
        assert!(err.is_err());
        // Geometry mismatch between draft and target is rejected too.
        let mut other_cfg = PicoLlamaConfig::test();
        other_cfg.vocab = qm.config.vocab;
        other_cfg.d_model *= 2;
        let other = Checkpoint::random_init(&other_cfg, 5);
        let err = Server::start(
            Backend::Reference(Box::new(other)),
            ServerConfig {
                draft: Some(pm),
                ..Default::default()
            },
        );
        assert!(err.is_err());
    }

    fn setup() -> (crate::model::quantized::QuantizedModel, Vec<McqProblem>) {
        let world = crate::data::FactWorld::generate(16, 4, 8, 1);
        let mut cfg = PicoLlamaConfig::test();
        cfg.vocab = world.vocab_size();
        let ck = Checkpoint::random_init(&cfg, 3);
        let qm =
            quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let problems = crate::data::generate_problems(&world, 24, 3);
        (qm, problems)
    }

    #[test]
    fn packed_backend_serves_and_batches() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let server = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                max_wait: Duration::from_millis(20),
                max_batch: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let rx: Vec<_> = problems.iter().map(|p| server.submit(p.clone())).collect();
        let mut max_batch = 0;
        let mut n = 0;
        for r in rx {
            let resp = r.recv().unwrap().unwrap();
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
            assert!(resp.latency() >= resp.timing.queue);
            assert!(resp.latency() >= resp.timing.ttft());
            max_batch = max_batch.max(resp.batch_size);
            n += 1;
        }
        assert_eq!(n, problems.len());
        assert!(max_batch > 1, "burst must batch");
    }

    #[test]
    fn batcher_honors_deadline_and_full_batches() {
        let (qm, problems) = setup();
        // A lone request with a large max_wait and room in the batch
        // must wait out (approximately) the deadline...
        let waiting = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(120),
                max_batch: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let resp = waiting.score(problems[0].clone()).unwrap();
        assert!(
            resp.timing.queue >= Duration::from_millis(90),
            "lone request should wait near the deadline, waited {:?}",
            resp.timing.queue
        );
        assert_eq!(resp.batch_size, 1);

        // ...while a full batch executes immediately: with max_batch=1 a
        // huge deadline must not delay the response.
        let eager = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_secs(30),
                max_batch: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let t0 = Instant::now();
        let resp = eager.score(problems[1].clone()).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "full batch must not wait for the deadline"
        );
        assert_eq!(resp.batch_size, 1);
    }

    #[test]
    fn prefix_cache_hit_matches_cold_miss() {
        let (qm, problems) = setup();
        let server = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(1),
                prefix_cache: 16,
                ..Default::default()
            },
        )
        .unwrap();
        // Same problem twice: the second scoring hits the prompt cache
        // and must return identical logprobs.
        let cold = server.score(problems[0].clone()).unwrap();
        let hit = server.score(problems[0].clone()).unwrap();
        assert_eq!(cold.result.logprobs, hit.result.logprobs);
        assert_eq!(cold.result.chosen, hit.result.chosen);
        // And a cache-disabled server agrees too.
        let uncached = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(1),
                prefix_cache: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let none = uncached.score(problems[0].clone()).unwrap();
        assert_eq!(cold.result.logprobs, none.result.logprobs);
    }

    #[test]
    fn sharded_batch_matches_sequential_executor() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let sharded = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig {
                max_wait: Duration::from_millis(50),
                max_batch: 16,
                workers: 4,
                prefix_cache: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let sequential = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                max_wait: Duration::from_millis(50),
                max_batch: 16,
                workers: 1,
                prefix_cache: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_a: Vec<_> = problems.iter().map(|p| sharded.submit(p.clone())).collect();
        let rx_b: Vec<_> = problems.iter().map(|p| sequential.submit(p.clone())).collect();
        for (a, b) in rx_a.into_iter().zip(rx_b) {
            let a = a.recv().unwrap().unwrap();
            let b = b.recv().unwrap().unwrap();
            assert_eq!(a.result.logprobs, b.result.logprobs, "sharding changed results");
            assert_eq!(a.result.chosen, b.result.chosen);
        }
    }

    #[test]
    fn malformed_request_errors_without_killing_the_server() {
        let (qm, problems) = setup();
        let server = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig::default(),
        )
        .unwrap();
        // Out-of-vocab token, empty prompt, over-long prompt: each must
        // come back as an error response, not a worker panic.
        let mut bad_vocab = problems[0].clone();
        bad_vocab.prompt[0] = 10_000;
        let mut empty_prompt = problems[0].clone();
        empty_prompt.prompt.clear();
        let mut too_long = problems[0].clone();
        too_long.prompt = vec![1; qm.config.max_seq + 1];
        for bad in [bad_vocab.clone(), empty_prompt, too_long] {
            assert!(server.score(bad).is_err());
        }
        // The server is still alive and scores valid problems.
        let ok = server.score(problems[0].clone()).unwrap();
        assert!(ok.result.logprobs.len() == problems[0].options.len());

        // A malformed request batched together with valid ones fails
        // alone; its batch-mates still succeed.
        let slow = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(200),
                max_batch: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_bad = slow.submit(bad_vocab);
        let rx_good = slow.submit(problems[1].clone());
        assert!(rx_bad.recv().unwrap().is_err());
        let good = rx_good.recv().unwrap().unwrap();
        assert!(good.result.logprobs.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn scalar_kernel_impl_and_row_workers_agree_with_default() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let lut = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig {
                row_workers: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let scalar = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                kernel_impl: KernelImpl::Scalar,
                row_workers: 1,
                ..Default::default()
            },
        )
        .unwrap();
        for p in problems.iter().take(8) {
            let a = lut.score(p.clone()).unwrap();
            let b = scalar.score(p.clone()).unwrap();
            for (la, lb) in a.result.logprobs.iter().zip(&b.result.logprobs) {
                assert!((la - lb).abs() < 1e-4, "lut {la} vs scalar {lb}");
            }
        }
    }

    #[test]
    fn packed_and_reference_backends_agree() {
        let (qm, problems) = setup();
        let packed = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig::default(),
        )
        .unwrap();
        let reference = Server::start(
            Backend::Reference(Box::new(qm.effective_checkpoint())),
            ServerConfig::default(),
        )
        .unwrap();
        for p in &problems {
            let a = packed.score(p.clone()).unwrap();
            let b = reference.score(p.clone()).unwrap();
            // The engines agree on every decided problem; only a near-tie
            // on this untrained checkpoint may flip under FP reordering.
            if a.result.chosen != b.result.chosen {
                assert!(b.result.margin() < 1e-3, "margin {}", b.result.margin());
            }
        }
    }

    #[test]
    fn full_recompute_baseline_matches_prefix_reuse() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let fast = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig::default(),
        )
        .unwrap();
        let baseline = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                reuse_prefix: false,
                prefix_cache: 0,
                ..Default::default()
            },
        )
        .unwrap();
        for p in problems.iter().take(8) {
            let a = fast.score(p.clone()).unwrap();
            let b = baseline.score(p.clone()).unwrap();
            for (la, lb) in a.result.logprobs.iter().zip(&b.result.logprobs) {
                assert!((la - lb).abs() < 1e-6, "{la} vs {lb}");
            }
        }
    }

    #[test]
    fn generation_streams_and_frees_blocks() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let cfg = pm.config.clone();
        let server = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig::builder().kv_block_positions(4).build().unwrap(),
        )
        .unwrap();
        let prompt = &problems[0].prompt;
        let n_new = 6usize;
        // Sequential oracle on a contiguous (owned) state.
        let mut ws = Workspace::new(&cfg, cfg.max_seq);
        let mut scratch = pm.prewarmed_scratch();
        let mut state = DecodeState::new(&cfg);
        let oracle = pm
            .generate_greedy(prompt, n_new, &mut ws, &mut scratch, &mut state)
            .unwrap();
        // Streamed continuous-batching path.
        let stream = server
            .submit_generate(GenerateRequest {
                prompt: prompt.clone(),
                max_tokens: n_new,
                deadline: None,
            })
            .unwrap();
        let mut streamed = Vec::new();
        let mut done = None;
        for ev in stream.iter() {
            match ev {
                TokenEvent::Token { index, token } => {
                    assert_eq!(index, streamed.len(), "tokens arrive in order");
                    streamed.push(token);
                }
                TokenEvent::Done(resp) => done = Some(resp),
                TokenEvent::Error(e) => panic!("stream failed: {e}"),
            }
        }
        let done = done.expect("stream must end with Done");
        assert_eq!(streamed, oracle, "streamed tokens must match sequential greedy");
        assert_eq!(done.tokens, oracle);
        assert_eq!(done.finish, FinishReason::MaxTokens);
        assert!(done.timing.ttft() <= done.timing.total());
        // All blocks return to the arena once the session retires.
        assert_eq!(server.kv_blocks_in_use(), 0);
    }

    #[test]
    fn scoring_and_generation_interleave() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let server = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig::default(),
        )
        .unwrap();
        // Kick off a generation, then score while it streams; both must
        // complete and agree with their solo counterparts.
        let stream = server
            .submit_generate(GenerateRequest {
                prompt: problems[0].prompt.clone(),
                max_tokens: 8,
                deadline: None,
            })
            .unwrap();
        let scored = server.score(problems[1].clone()).unwrap();
        assert_eq!(scored.result.logprobs.len(), problems[1].options.len());
        let gen = stream.wait().unwrap();
        assert!(!gen.tokens.is_empty());
        assert_eq!(server.kv_blocks_in_use(), 0);
    }
}
