//! Batched inference server: the deployment-side driver (examples/
//! edge_deploy.rs) that serves MCQ scoring requests from a quantized
//! model with dynamic batching — the "edge AI device" role the paper
//! targets, on the rust+PJRT runtime.
//!
//! Architecture (std threads; no tokio in the offline build):
//!
//! ```text
//!   clients ──(mpsc)──▶ batcher ──(collect ≤B, ≤max_wait)──▶ executor
//!                          ▲                                   │
//!                          └──────── responses (per-request oneshot)
//! ```
//!
//! The batcher groups pending requests up to the engine's compiled batch
//! size or until `max_wait` expires — standard dynamic batching (the
//! vLLM-router pattern, scaled to this workload).

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::data::McqProblem;
use crate::eval::ProblemResult;
use crate::runtime::{ArgValue, Engine};

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// One scoring request.
pub struct Request {
    pub problem: McqProblem,
    /// Sender for the response.
    respond: mpsc::Sender<Result<Response>>,
    enqueued: Instant,
}

/// One scoring response with timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub result: ProblemResult,
    pub queue_time: Duration,
    pub batch_size: usize,
}

/// Server handle: submit requests, join on drop.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<thread::JoinHandle<()>>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Variant to execute (e.g. "score_quant_k3").
    pub variant: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(5),
            variant: "score_quant_k3".to_string(),
        }
    }
}

impl Server {
    /// Spawn the batcher/executor thread. The PJRT engine is constructed
    /// *inside* the worker (the xla client is not Send); startup errors
    /// are returned synchronously through a handshake channel.
    pub fn start(
        artifacts_dir: PathBuf,
        weight_args: BTreeMap<String, ArgValue>,
        config: ServerConfig,
    ) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let variant = config.variant.clone();
        let worker = thread::spawn(move || {
            let engine = match Engine::load(&artifacts_dir, Some(&[variant.as_str()])) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            batch_loop(&engine, &weight_args, &config, rx);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))??;
        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
        })
    }

    /// Submit a problem; returns a receiver for the response.
    pub fn submit(&self, problem: McqProblem) -> mpsc::Receiver<Result<Response>> {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            problem,
            respond: rtx,
            enqueued: Instant::now(),
        };
        if let Some(tx) = &self.tx {
            // A dropped batcher surfaces as a closed response channel.
            let _ = tx.send(req);
        }
        rrx
    }

    /// Submit synchronously.
    pub fn score(&self, problem: McqProblem) -> Result<Response> {
        self.submit(problem)
            .recv()
            .map_err(|_| anyhow!("server stopped"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue → batcher exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batch_loop(
    engine: &Engine,
    weight_args: &BTreeMap<String, ArgValue>,
    config: &ServerConfig,
    rx: mpsc::Receiver<Request>,
) {
    let max_batch = engine.batch;
    loop {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + config.max_wait;
        // Fill greedily until the batch is full or the deadline passes.
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        execute_batch(engine, weight_args, config, batch);
    }
}

fn execute_batch(
    engine: &Engine,
    weight_args: &BTreeMap<String, ArgValue>,
    config: &ServerConfig,
    batch: Vec<Request>,
) {
    let problems: Vec<McqProblem> = batch.iter().map(|r| r.problem.clone()).collect();
    let n = batch.len();
    match per_problem_results(engine, weight_args, config, &problems) {
        Ok(results) => {
            for (req, result) in batch.into_iter().zip(results) {
                let resp = Response {
                    result,
                    queue_time: req.enqueued.elapsed(),
                    batch_size: n,
                };
                let _ = req.respond.send(Ok(resp));
            }
        }
        Err(e) => fail_all(batch, &e),
    }
}

fn fail_all(batch: Vec<Request>, e: &anyhow::Error) {
    for req in batch {
        let _ = req.respond.send(Err(anyhow!("batch failed: {e}")));
    }
}

/// Execute one batch and return per-problem results.
fn per_problem_results(
    engine: &Engine,
    weight_args: &BTreeMap<String, ArgValue>,
    config: &ServerConfig,
    problems: &[McqProblem],
) -> Result<Vec<ProblemResult>> {
    // score_problems pads internally; its report is aggregate only, so
    // inline the batching here for per-problem outputs.
    let b = engine.batch;
    let plen = engine.prompt_len;
    let mut results = Vec::with_capacity(problems.len());
    for chunk in problems.chunks(b) {
        let mut tokens = Vec::with_capacity(b * plen);
        for p in chunk {
            tokens.extend(p.prompt.iter().map(|&t| t as i32));
        }
        for _ in chunk.len()..b {
            tokens.extend(chunk[0].prompt.iter().map(|&t| t as i32));
        }
        let mut args = (*weight_args).clone();
        args.insert("tokens".to_string(), ArgValue::I32(tokens));
        let logits = engine.execute(&config.variant, &args)?;
        for (i, p) in chunk.iter().enumerate() {
            let row = logits.row(i);
            let lps: Vec<f64> = p
                .options
                .iter()
                .map(|opt| crate::model::forward::log_prob(row, opt[0]))
                .collect();
            let chosen = lps
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            results.push(ProblemResult {
                chosen,
                correct: p.correct,
                logprobs: lps,
            });
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    // Server tests that need real artifacts live in rust/tests/
    // integration; here we only test the queueing scaffolding compiles
    // and the config defaults are sane.
    use super::*;

    #[test]
    fn config_defaults() {
        let c = ServerConfig::default();
        assert!(c.max_wait <= Duration::from_millis(50));
        assert!(c.variant.starts_with("score_"));
    }
}
