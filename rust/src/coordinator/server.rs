//! Batched inference server: the deployment-side driver (examples/
//! edge_deploy.rs) that serves MCQ scoring requests from a quantized
//! model with dynamic batching — the "edge AI device" role the paper
//! targets.
//!
//! Architecture (std threads; no tokio in the offline build):
//!
//! ```text
//!   clients ──(mpsc)──▶ batcher ──(collect ≤B, ≤max_wait)──▶ executor
//!                          ▲                 │ shard across worker pool
//!                          │                 │ (per-worker ScoreBuffers,
//!                          │                 │  shared prompt-prefix LRU)
//!                          └──────── responses (per-request oneshot)
//! ```
//!
//! The batcher groups pending requests up to the executor's batch size
//! or until `max_wait` expires — standard dynamic batching (the
//! vLLM-router pattern, scaled to this workload). The CPU executors
//! then **shard the batch across a worker pool** (`workers` threads,
//! each holding its own workspace/decode-state/kernel-scratch) and
//! score each problem with **prefix reuse**: one prompt pass + one
//! short extension per option, consulting a bounded LRU
//! [`PrefixCache`] keyed by prompt tokens so concurrent requests that
//! share a prompt reuse its computed K/V instead of recomputing it.
//!
//! Three execution backends ([`Backend`]):
//! * **Packed** — the packed-integer kernel engine
//!   ([`crate::model::packed::PackedModel`]): scores straight on the
//!   bit-packed planes, no PJRT artifacts or f32 weight dequants needed.
//! * **Reference** — the CPU reference forward over an effective
//!   (dequantized) f32 checkpoint.
//! * **Pjrt** — the AOT-compiled PJRT variants (requires `artifacts/`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::data::McqProblem;
use crate::eval::{self, nan_safe_argmax, ProblemResult, ScoreBuffers};
use crate::kernels::KernelImpl;
use crate::model::decode::PrefixCache;
use crate::model::packed::PackedModel;
use crate::model::Checkpoint;
use crate::runtime::{ArgValue, Engine};
use crate::util::pool::{thread_budget, Pool};

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// One scoring request.
pub struct Request {
    pub problem: McqProblem,
    /// Sender for the response.
    respond: mpsc::Sender<Result<Response>>,
    enqueued: Instant,
}

/// One scoring response with timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub result: ProblemResult,
    /// Time spent queued (enqueue → the batch starting to execute).
    pub queue_time: Duration,
    /// Time the batch spent executing (shared by its members).
    pub exec_time: Duration,
    pub batch_size: usize,
}

impl Response {
    /// End-to-end latency: queueing plus batch execution.
    pub fn latency(&self) -> Duration {
        self.queue_time + self.exec_time
    }
}

/// Server handle: submit requests, join on drop.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<thread::JoinHandle<()>>,
}

/// How the worker thread executes a batch.
pub enum Backend {
    /// AOT-compiled PJRT variants. The engine is constructed *inside*
    /// the worker thread (the xla client is not Send).
    Pjrt {
        artifacts_dir: PathBuf,
        weight_args: BTreeMap<String, ArgValue>,
    },
    /// Packed-integer kernel engine (CPU; no artifacts required).
    Packed(Box<PackedModel>),
    /// CPU reference forward over an effective f32 checkpoint.
    Reference(Box<Checkpoint>),
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// PJRT variant to execute (e.g. "score_quant_k3"); ignored by the
    /// CPU backends.
    pub variant: String,
    /// Batch size for the CPU backends (PJRT uses the compiled batch).
    pub max_batch: usize,
    /// Worker threads a CPU executor shards a batch across (each holds
    /// its own `ScoreBuffers`). 0 = available parallelism; PJRT ignores
    /// this (the compiled executable is the batch executor).
    pub workers: usize,
    /// Prompt-prefix LRU capacity in entries (0 disables the cache).
    pub prefix_cache: usize,
    /// Score with prefix reuse (one prompt pass + per-option
    /// extensions). `false` falls back to the seed full-recompute path —
    /// kept as a benchmarking baseline (`perf_probe --serving-json`).
    pub reuse_prefix: bool,
    /// Packed-kernel inner loops: the LUT-fused default or the scalar
    /// oracle (`--kernel-impl`). The reference backend ignores this.
    pub kernel_impl: KernelImpl,
    /// Threads each packed executor worker shards large GEMV output
    /// rows across (`--row-workers`). 0 = auto: the cores left over
    /// after batch-level sharding (`thread_budget`), so a one-worker
    /// server decoding a single stream uses every core per token while
    /// a saturated batch pool stays row-serial.
    pub row_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(5),
            variant: "score_quant_k3".to_string(),
            max_batch: 16,
            workers: 1,
            prefix_cache: 32,
            reuse_prefix: true,
            kernel_impl: KernelImpl::default(),
            row_workers: 0,
        }
    }
}

impl ServerConfig {
    fn make_pool(&self) -> Pool {
        if self.workers == 0 {
            Pool::new_auto()
        } else {
            Pool::new(self.workers)
        }
    }

    /// The shared row pool packed executor workers attach to their
    /// kernel scratch, or `None` when the budget leaves no spare cores.
    fn make_row_pool(&self, batch_workers: usize) -> Option<Arc<Pool>> {
        let row = if self.row_workers > 0 {
            self.row_workers
        } else {
            let total = thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
            thread_budget(total, batch_workers).1
        };
        (row > 1).then(|| Arc::new(Pool::new(row)))
    }
}

impl Server {
    /// Spawn the batcher/executor thread for a backend. Startup errors
    /// (e.g. PJRT compile failures) are returned synchronously through a
    /// handshake channel.
    pub fn start(backend: Backend, config: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = thread::spawn(move || {
            let mut exec = match backend {
                Backend::Pjrt {
                    artifacts_dir,
                    weight_args,
                } => match Engine::load(&artifacts_dir, Some(&[config.variant.as_str()])) {
                    Ok(engine) => Executor::Pjrt {
                        engine,
                        weight_args,
                    },
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                },
                // CPU backends own a worker pool, a shared prefix cache
                // and one checkout slot of scoring buffers per worker,
                // all for the batcher thread's lifetime — the serving
                // hot path does no per-batch buffer allocation.
                Backend::Packed(pm) => {
                    let pool = config.make_pool();
                    // Thread budget: cores beyond the batch-level pool
                    // go to intra-forward row sharding — a single
                    // decode stream then scales with cores instead of
                    // pinning one.
                    let row_pool = config.make_row_pool(pool.size());
                    let bufs = (0..pool.size())
                        .map(|_| {
                            let mut b = ScoreBuffers::for_packed(&pm, pm.config.max_seq);
                            b.scratch.set_kernel_impl(config.kernel_impl);
                            b.scratch.set_row_pool(row_pool.clone());
                            Mutex::new(b)
                        })
                        .collect();
                    Executor::Packed {
                        pm,
                        pool,
                        cache: Mutex::new(PrefixCache::new(config.prefix_cache)),
                        bufs,
                    }
                }
                Backend::Reference(ck) => {
                    let pool = config.make_pool();
                    let bufs = (0..pool.size())
                        .map(|_| Mutex::new(ScoreBuffers::new(&ck.config, ck.config.max_seq)))
                        .collect();
                    Executor::Reference {
                        ck,
                        pool,
                        cache: Mutex::new(PrefixCache::new(config.prefix_cache)),
                        bufs,
                    }
                }
            };
            let _ = ready_tx.send(Ok(()));
            batch_loop(&mut exec, &config, rx);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))??;
        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
        })
    }

    /// Submit a problem; returns a receiver for the response.
    pub fn submit(&self, problem: McqProblem) -> mpsc::Receiver<Result<Response>> {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            problem,
            respond: rtx,
            enqueued: Instant::now(),
        };
        if let Some(tx) = &self.tx {
            // A dropped batcher surfaces as a closed response channel.
            let _ = tx.send(req);
        }
        rrx
    }

    /// Submit synchronously.
    pub fn score(&self, problem: McqProblem) -> Result<Response> {
        self.submit(problem)
            .recv()
            .map_err(|_| anyhow!("server stopped"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue → batcher exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The worker-side executor (lives entirely on the batcher thread). The
/// CPU backends shard each batch across their pool; every pool worker
/// checks out one batcher-lifetime [`ScoreBuffers`] slot (workspace +
/// decode state + prewarmed kernel scratch, reused across batches) and
/// the workers share the batcher-lifetime prompt-prefix cache.
enum Executor {
    Pjrt {
        engine: Engine,
        weight_args: BTreeMap<String, ArgValue>,
    },
    Packed {
        pm: Box<PackedModel>,
        pool: Pool,
        cache: Mutex<PrefixCache>,
        bufs: Vec<Mutex<ScoreBuffers>>,
    },
    Reference {
        ck: Box<Checkpoint>,
        pool: Pool,
        cache: Mutex<PrefixCache>,
        bufs: Vec<Mutex<ScoreBuffers>>,
    },
}

/// Shard one batch across the executor pool: every sweep worker checks
/// out a distinct long-lived buffer slot (the atomic ticket makes
/// indices unique and `workers <= bufs.len()` — the pool never runs
/// more workers than its size — so the lock never blocks) and scores
/// the problems it claims through `score_one`. Shared by the Packed and
/// Reference arms so the sharding/checkout logic cannot drift between
/// engines.
fn shard_batch<F>(
    pool: &Pool,
    bufs: &[Mutex<ScoreBuffers>],
    problems: &[McqProblem],
    score_one: F,
) -> Vec<Result<ProblemResult>>
where
    F: Fn(&mut ScoreBuffers, &McqProblem) -> Result<ProblemResult> + Sync,
{
    let ticket = AtomicUsize::new(0);
    pool.parallel_map_init(
        problems.len(),
        || bufs[ticket.fetch_add(1, Ordering::Relaxed) % bufs.len()].lock().unwrap(),
        |guard, i| score_one(guard, &problems[i]),
    )
}

impl Executor {
    fn max_batch(&self, config: &ServerConfig) -> usize {
        match self {
            Executor::Pjrt { engine, .. } => engine.batch,
            _ => config.max_batch.max(1),
        }
    }

    /// Score a batch. The outer `Err` is a whole-batch failure (e.g. a
    /// PJRT execution error); the inner per-problem `Result`s carry
    /// request-level errors (a malformed problem fails alone — valid
    /// requests batched with it still succeed).
    fn score(
        &mut self,
        config: &ServerConfig,
        problems: &[McqProblem],
    ) -> Result<Vec<Result<ProblemResult>>> {
        match self {
            Executor::Pjrt {
                engine,
                weight_args,
            } => {
                // Per-problem shape validation: a mismatched or
                // malformed request fails alone (instead of panicking
                // the batcher); the valid subset still executes.
                let plen = engine.prompt_len;
                let mut out: Vec<Option<Result<ProblemResult>>> = problems
                    .iter()
                    .map(|p| {
                        if p.prompt.len() != plen {
                            Some(Err(anyhow!(
                                "prompt length {} != the engine's compiled prompt_len \
                                 {plen}; this problem cannot be scored by variant '{}'",
                                p.prompt.len(),
                                config.variant
                            )))
                        } else if p.options.is_empty() || p.options.iter().any(|o| o.is_empty()) {
                            Some(Err(anyhow!("problem has empty options")))
                        } else {
                            None
                        }
                    })
                    .collect();
                let valid: Vec<McqProblem> = problems
                    .iter()
                    .zip(&out)
                    .filter(|(_, slot)| slot.is_none())
                    .map(|(p, _)| p.clone())
                    .collect();
                let mut scored =
                    per_problem_results(engine, weight_args, config, &valid)?.into_iter();
                Ok(out
                    .into_iter()
                    .map(|slot| {
                        slot.unwrap_or_else(|| scored.next().expect("one result per problem"))
                    })
                    .collect())
            }
            Executor::Packed {
                pm,
                pool,
                cache,
                bufs,
            } => {
                let pm: &PackedModel = pm;
                let cache: &Mutex<PrefixCache> = cache;
                Ok(shard_batch(pool, bufs, problems, |bufs, p| {
                    eval::validate_problem(&pm.config, p)?;
                    if config.reuse_prefix {
                        let ScoreBuffers { ws, state, scratch } = bufs;
                        eval::score_problem_session(&mut pm.ops(scratch), p, ws, state, Some(cache))
                    } else {
                        eval::score_problem_packed_full(pm, p, &mut bufs.ws, &mut bufs.scratch)
                    }
                }))
            }
            Executor::Reference {
                ck,
                pool,
                cache,
                bufs,
            } => {
                let ck: &Checkpoint = ck;
                let cache: &Mutex<PrefixCache> = cache;
                Ok(shard_batch(pool, bufs, problems, |bufs, p| {
                    eval::validate_problem(&ck.config, p)?;
                    if config.reuse_prefix {
                        let mut ops = crate::model::forward::CkOps::new(ck);
                        eval::score_problem_session(
                            &mut ops,
                            p,
                            &mut bufs.ws,
                            &mut bufs.state,
                            Some(cache),
                        )
                    } else {
                        eval::score_problem_full(ck, p, &mut bufs.ws)
                    }
                }))
            }
        }
    }
}

fn batch_loop(exec: &mut Executor, config: &ServerConfig, rx: mpsc::Receiver<Request>) {
    let max_batch = exec.max_batch(config);
    loop {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + config.max_wait;
        // Fill greedily until the batch is full or the deadline passes.
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        execute_batch(exec, config, batch);
    }
}

fn execute_batch(exec: &mut Executor, config: &ServerConfig, batch: Vec<Request>) {
    let problems: Vec<McqProblem> = batch.iter().map(|r| r.problem.clone()).collect();
    let n = batch.len();
    let started = Instant::now();
    match exec.score(config, &problems) {
        Ok(results) => {
            let exec_time = started.elapsed();
            for (req, result) in batch.into_iter().zip(results) {
                let resp = result.map(|result| Response {
                    result,
                    queue_time: started.duration_since(req.enqueued),
                    exec_time,
                    batch_size: n,
                });
                let _ = req.respond.send(resp);
            }
        }
        Err(e) => fail_all(batch, &e),
    }
}

fn fail_all(batch: Vec<Request>, e: &anyhow::Error) {
    for req in batch {
        let _ = req.respond.send(Err(anyhow!("batch failed: {e}")));
    }
}

/// Execute one PJRT batch and return per-problem results. Callers
/// ([`Executor::score`]) have already shape-validated every problem
/// (prompt length, non-empty options); token-range errors that only
/// surface against the executed logits (an out-of-vocab option) come
/// back as that problem's inner `Err`.
fn per_problem_results(
    engine: &Engine,
    weight_args: &BTreeMap<String, ArgValue>,
    config: &ServerConfig,
    problems: &[McqProblem],
) -> Result<Vec<Result<ProblemResult>>> {
    // score_problems pads internally; its report is aggregate only, so
    // inline the batching here for per-problem outputs.
    let b = engine.batch;
    let plen = engine.prompt_len;
    let mut results = Vec::with_capacity(problems.len());
    for chunk in problems.chunks(b) {
        let mut tokens = Vec::with_capacity(b * plen);
        for p in chunk {
            debug_assert_eq!(p.prompt.len(), plen, "caller pre-validates prompt length");
            tokens.extend(p.prompt.iter().map(|&t| t as i32));
        }
        // Pad the final chunk with neutral all-<pad> prompts of the
        // engine's prompt_len; the padding rows' logits are discarded.
        tokens.resize(b * plen, crate::data::PAD as i32);
        let mut args = (*weight_args).clone();
        args.insert("tokens".to_string(), ArgValue::I32(tokens));
        let logits = engine.execute(&config.variant, &args)?;
        let vocab = logits.shape()[1];
        for (i, p) in chunk.iter().enumerate() {
            let row = logits.row(i);
            let lps: Result<Vec<f64>> = p
                .options
                .iter()
                .map(|opt| {
                    if opt[0] >= vocab {
                        bail!("option token {} out of vocab {vocab}", opt[0]);
                    }
                    Ok(crate::model::forward::log_prob(row, opt[0]))
                })
                .collect();
            // NaN logprobs (a poisoned batch) must not panic the batch
            // thread: treat them as -inf and let the result surface.
            results.push(lps.map(|lps| ProblemResult {
                chosen: nan_safe_argmax(&lps),
                correct: p.correct,
                logprobs: lps,
            }));
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    // Server tests that need real PJRT artifacts live in rust/tests/
    // integration; here we test the queueing scaffolding with the CPU
    // backends and the config defaults.
    use super::*;
    use crate::model::quantized::{quantize_model, Method};
    use crate::model::PicoLlamaConfig;
    use crate::quant::Bits;
    use crate::split::SplitConfig;

    #[test]
    fn config_defaults() {
        let c = ServerConfig::default();
        assert!(c.max_wait <= Duration::from_millis(50));
        assert!(c.variant.starts_with("score_"));
        assert!(c.max_batch >= 1);
        assert!(c.workers >= 1, "default avoids surprise thread fan-out");
        assert!(c.reuse_prefix, "prefix reuse is the default scoring path");
    }

    fn setup() -> (crate::model::quantized::QuantizedModel, Vec<McqProblem>) {
        let world = crate::data::FactWorld::generate(16, 4, 8, 1);
        let mut cfg = PicoLlamaConfig::test();
        cfg.vocab = world.vocab_size();
        let ck = Checkpoint::random_init(&cfg, 3);
        let qm =
            quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let problems = crate::data::generate_problems(&world, 24, 3);
        (qm, problems)
    }

    #[test]
    fn packed_backend_serves_and_batches() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let server = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                max_wait: Duration::from_millis(20),
                max_batch: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let rx: Vec<_> = problems.iter().map(|p| server.submit(p.clone())).collect();
        let mut max_batch = 0;
        let mut n = 0;
        for r in rx {
            let resp = r.recv().unwrap().unwrap();
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
            assert!(resp.latency() >= resp.queue_time);
            max_batch = max_batch.max(resp.batch_size);
            n += 1;
        }
        assert_eq!(n, problems.len());
        assert!(max_batch > 1, "burst must batch");
    }

    #[test]
    fn batcher_honors_deadline_and_full_batches() {
        let (qm, problems) = setup();
        // A lone request with a large max_wait and room in the batch
        // must wait out (approximately) the deadline...
        let waiting = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(120),
                max_batch: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let resp = waiting.score(problems[0].clone()).unwrap();
        assert!(
            resp.queue_time >= Duration::from_millis(90),
            "lone request should wait near the deadline, waited {:?}",
            resp.queue_time
        );
        assert_eq!(resp.batch_size, 1);

        // ...while a full batch executes immediately: with max_batch=1 a
        // huge deadline must not delay the response.
        let eager = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_secs(30),
                max_batch: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let t0 = Instant::now();
        let resp = eager.score(problems[1].clone()).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "full batch must not wait for the deadline"
        );
        assert_eq!(resp.batch_size, 1);
    }

    #[test]
    fn prefix_cache_hit_matches_cold_miss() {
        let (qm, problems) = setup();
        let server = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(1),
                prefix_cache: 16,
                ..Default::default()
            },
        )
        .unwrap();
        // Same problem twice: the second scoring hits the prompt cache
        // and must return identical logprobs.
        let cold = server.score(problems[0].clone()).unwrap();
        let hit = server.score(problems[0].clone()).unwrap();
        assert_eq!(cold.result.logprobs, hit.result.logprobs);
        assert_eq!(cold.result.chosen, hit.result.chosen);
        // And a cache-disabled server agrees too.
        let uncached = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(1),
                prefix_cache: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let none = uncached.score(problems[0].clone()).unwrap();
        assert_eq!(cold.result.logprobs, none.result.logprobs);
    }

    #[test]
    fn sharded_batch_matches_sequential_executor() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let sharded = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig {
                max_wait: Duration::from_millis(50),
                max_batch: 16,
                workers: 4,
                prefix_cache: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let sequential = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                max_wait: Duration::from_millis(50),
                max_batch: 16,
                workers: 1,
                prefix_cache: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_a: Vec<_> = problems.iter().map(|p| sharded.submit(p.clone())).collect();
        let rx_b: Vec<_> = problems.iter().map(|p| sequential.submit(p.clone())).collect();
        for (a, b) in rx_a.into_iter().zip(rx_b) {
            let a = a.recv().unwrap().unwrap();
            let b = b.recv().unwrap().unwrap();
            assert_eq!(a.result.logprobs, b.result.logprobs, "sharding changed results");
            assert_eq!(a.result.chosen, b.result.chosen);
        }
    }

    #[test]
    fn malformed_request_errors_without_killing_the_server() {
        let (qm, problems) = setup();
        let server = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig::default(),
        )
        .unwrap();
        // Out-of-vocab token, empty prompt, over-long prompt: each must
        // come back as an error response, not a worker panic.
        let mut bad_vocab = problems[0].clone();
        bad_vocab.prompt[0] = 10_000;
        let mut empty_prompt = problems[0].clone();
        empty_prompt.prompt.clear();
        let mut too_long = problems[0].clone();
        too_long.prompt = vec![1; qm.config.max_seq + 1];
        for bad in [bad_vocab.clone(), empty_prompt, too_long] {
            assert!(server.score(bad).is_err());
        }
        // The server is still alive and scores valid problems.
        let ok = server.score(problems[0].clone()).unwrap();
        assert!(ok.result.logprobs.len() == problems[0].options.len());

        // A malformed request batched together with valid ones fails
        // alone; its batch-mates still succeed.
        let slow = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig {
                max_wait: Duration::from_millis(200),
                max_batch: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_bad = slow.submit(bad_vocab);
        let rx_good = slow.submit(problems[1].clone());
        assert!(rx_bad.recv().unwrap().is_err());
        let good = rx_good.recv().unwrap().unwrap();
        assert!(good.result.logprobs.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn scalar_kernel_impl_and_row_workers_agree_with_default() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let lut = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig {
                row_workers: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let scalar = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                kernel_impl: KernelImpl::Scalar,
                row_workers: 1,
                ..Default::default()
            },
        )
        .unwrap();
        for p in problems.iter().take(8) {
            let a = lut.score(p.clone()).unwrap();
            let b = scalar.score(p.clone()).unwrap();
            for (la, lb) in a.result.logprobs.iter().zip(&b.result.logprobs) {
                assert!((la - lb).abs() < 1e-4, "lut {la} vs scalar {lb}");
            }
        }
    }

    #[test]
    fn packed_and_reference_backends_agree() {
        let (qm, problems) = setup();
        let packed = Server::start(
            Backend::Packed(Box::new(PackedModel::from_qmodel(&qm).unwrap())),
            ServerConfig::default(),
        )
        .unwrap();
        let reference = Server::start(
            Backend::Reference(Box::new(qm.effective_checkpoint())),
            ServerConfig::default(),
        )
        .unwrap();
        for p in &problems {
            let a = packed.score(p.clone()).unwrap();
            let b = reference.score(p.clone()).unwrap();
            // The engines agree on every decided problem; only a near-tie
            // on this untrained checkpoint may flip under FP reordering.
            if a.result.chosen != b.result.chosen {
                assert!(b.result.margin() < 1e-3, "margin {}", b.result.margin());
            }
        }
    }

    #[test]
    fn full_recompute_baseline_matches_prefix_reuse() {
        let (qm, problems) = setup();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let fast = Server::start(
            Backend::Packed(Box::new(pm.clone())),
            ServerConfig::default(),
        )
        .unwrap();
        let baseline = Server::start(
            Backend::Packed(Box::new(pm)),
            ServerConfig {
                reuse_prefix: false,
                prefix_cache: 0,
                ..Default::default()
            },
        )
        .unwrap();
        for p in problems.iter().take(8) {
            let a = fast.score(p.clone()).unwrap();
            let b = baseline.score(p.clone()).unwrap();
            for (la, lb) in a.result.logprobs.iter().zip(&b.result.logprobs) {
                assert!((la - lb).abs() < 1e-6, "{la} vs {lb}");
            }
        }
    }
}
