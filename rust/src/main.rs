//! `splitquant` — CLI for the SplitQuantV2 reproduction.
//!
//! Subcommands:
//!   quantize   preprocess + quantize a checkpoint, write packed SQTZ
//!   eval       Table-1 grid (Original + INT{8,4,2} × baseline/SQv2)
//!   serve      batched MCQ scoring server demo over PJRT
//!   inspect    dump a checkpoint / quantized container
//!   report     per-layer resolution report (Figure 1 numbers)

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};
use splitquant::coordinator::{Coordinator, PipelineSpec};
use splitquant::runtime::EngineKind;
use splitquant::io::{checkpoint::load_checkpoint, qmodel, read_file};
use splitquant::model::quantized::Method;
use splitquant::model::{param_inventory, ParamKind};
use splitquant::quant::Bits;
use splitquant::split::{DynamicK, SplitConfig, Strategy};
use splitquant::util::cli::{App, Command, Matches};
use splitquant::util::fmt::{human_bytes, human_count, Table};
use splitquant::util::logging;
use splitquant::util::timer::format_duration;
use splitquant::{log_error, log_info};

fn app() -> App {
    App::new("splitquant", "SplitQuantV2: low-bit LLM quantization without GPUs")
        .command(
            Command::new("quantize", "preprocess + linearly quantize a checkpoint")
                .req("ckpt", "input FP checkpoint (.sqtz)")
                .req("out", "output quantized model (.sqtz)")
                .opt("bits", "4", "bit width (2|4|8)")
                .opt("method", "splitquant", "baseline|splitquant|ocs")
                .opt("k", "3", "clusters per layer (splitquant)")
                .opt("strategy", "masked", "masked|rowwise split structure")
                .opt("ocs-ratio", "0.05", "OCS channel expansion ratio")
                .flag("dynamic-k", "choose k per layer by inertia elbow")
                .opt("threads", "0", "pipeline worker threads (0 = all cores)")
                .opt("metrics-json", "", "write a final telemetry snapshot JSON to this path")
                .opt("log", "info", "log level"),
        )
        .command(
            Command::new("eval", "run the Table-1 grid on a checkpoint")
                .opt("ckpt", "artifacts/picollama_eval.sqtz", "FP checkpoint")
                .opt("problems", "artifacts/eval_problems.json", "problem set")
                .opt("k", "3", "clusters for the SplitQuantV2 arm")
                .opt("amplify-frac", "0.003", "outlier amplification fraction")
                .opt("amplify-gain", "4", "outlier amplification gain")
                .flag("no-amplify", "skip outlier amplification")
                .flag("runtime", "score through PJRT instead of the CPU reference")
                .opt("engine", "reference", "CPU engine for quantized arms: packed|reference")
                .opt("kernel-impl", "auto", "packed kernel inner loops: auto|simd|lut|scalar")
                .flag("speculative", "also run a speculative-vs-plain greedy decode check")
                .opt("draft-bits", "2", "draft bit width for --speculative (2|4)")
                .opt("draft-k", "4", "max draft tokens per speculative round")
                .opt("export-dir", "", "also export packed arms to this dir")
                .opt("threads", "0", "pipeline worker threads (0 = all cores)")
                .opt("metrics-json", "", "write a final telemetry snapshot JSON to this path")
                .opt("log", "info", "log level"),
        )
        .command(
            Command::new("serve", "batched scoring server demo")
                .opt("ckpt", "artifacts/picollama_eval.sqtz", "FP checkpoint")
                .opt("problems", "artifacts/eval_problems.json", "problem set")
                .opt("artifacts", "artifacts", "artifacts dir (HLO + manifest; pjrt engine only)")
                .opt("bits", "4", "bit width")
                .opt("engine", "packed", "execution engine: packed|reference (CPU) or pjrt")
                .opt("requests", "200", "number of requests to fire")
                .opt("max-batch", "16", "executor batch size (CPU engines)")
                .opt("max-wait-ms", "5", "batcher fill deadline in milliseconds")
                .opt("workers", "0", "executor pool workers, CPU engines (0 = all cores)")
                .opt("kernel-impl", "auto", "packed kernel inner loops: auto|simd|lut|scalar")
                .opt("row-workers", "0", "row-parallel GEMV threads (0 = cores left after batch workers)")
                .opt("prefix-cache", "32", "prompt-prefix LRU capacity (0 = disabled)")
                .flag("full-recompute", "score via full prompt+option recompute (baseline)")
                .flag("stream", "streaming generation instead of MCQ scoring (CPU engines)")
                .flag("speculative", "speculative decoding: low-bit draft + batched verify (stream mode)")
                .opt("draft-bits", "2", "draft model bit width (2|4)")
                .opt("draft-k", "4", "max draft tokens per speculative round")
                .opt("max-sessions", "64", "concurrent generation sessions (stream mode)")
                .opt("kv-blocks", "0", "KV arena blocks (0 = auto for max-sessions)")
                .opt("max-new-tokens", "8", "tokens to generate per request (stream mode)")
                .opt("deadline-ms", "0", "per-request deadline in milliseconds (0 = none)")
                .opt(
                    "drain-deadline-ms",
                    "0",
                    "graceful-drain deadline at shutdown (0 = wait for live sessions)",
                )
                .opt("watchdog-ms", "0", "cancel sessions whose decode step exceeds this (0 = off)")
                .opt("threads", "0", "pipeline worker threads (0 = all cores)")
                .opt("metrics-addr", "", "serve live metrics on this address (e.g. 127.0.0.1:9100)")
                .opt("metrics-json", "", "write a final telemetry snapshot JSON to this path")
                .opt("log", "info", "log level"),
        )
        .command(
            Command::new("inspect", "describe an .sqtz container")
                .pos("file", "checkpoint or quantized model"),
        )
        .command(
            Command::new("report", "per-layer resolution report (Figure 1)")
                .opt("ckpt", "artifacts/picollama_eval.sqtz", "FP checkpoint")
                .opt("bits", "4", "bit width")
                .opt("k", "3", "clusters")
                .opt("layer", "", "single layer name (default: all linear)"),
        )
}

fn parse_bits(m: &Matches) -> Result<Bits> {
    Bits::from_width(m.get_usize("bits")?)
}

/// `--draft-bits` for the speculative paths: the draft must be one of
/// the *low* widths (the whole point is a cheaper engine than the
/// target).
fn parse_draft_bits(m: &Matches) -> Result<Bits> {
    match m.get_usize("draft-bits")? {
        2 => Ok(Bits::Int2),
        4 => Ok(Bits::Int4),
        other => bail!("--draft-bits must be 2 or 4 (got {other})"),
    }
}

/// Telemetry lifecycle shared by the subcommands that support it:
/// `--metrics-addr` / `--metrics-json` turn the global registry on,
/// the former additionally starts the live `/metrics` endpoint (held
/// alive by this guard), and [`Telemetry::finish`] dumps the final
/// snapshot. With neither option set everything stays disabled and the
/// hot paths pay one relaxed atomic load per recording site.
struct Telemetry {
    _server: Option<splitquant::obs::http::MetricsServer>,
    json_path: Option<String>,
}

impl Telemetry {
    fn from_matches(m: &Matches) -> Result<Telemetry> {
        let addr = m.get_opt("metrics-addr").filter(|s| !s.is_empty());
        let json_path = m.get_opt("metrics-json").filter(|s| !s.is_empty());
        if addr.is_some() || json_path.is_some() {
            splitquant::obs::set_enabled(true);
        }
        let server = match addr {
            Some(a) => {
                let srv = splitquant::obs::http::serve(a)?;
                log_info!("metrics endpoint listening on http://{}/metrics", srv.addr());
                Some(srv)
            }
            None => None,
        };
        Ok(Telemetry {
            _server: server,
            json_path: json_path.map(String::from),
        })
    }

    /// Write the final snapshot (when `--metrics-json` asked for one).
    fn finish(&self) -> Result<()> {
        if let Some(path) = &self.json_path {
            let snap = splitquant::obs::snapshot().to_json().to_string_pretty();
            std::fs::write(path, snap)?;
            log_info!("wrote metrics snapshot to {path}");
        }
        Ok(())
    }
}

fn split_cfg(m: &Matches) -> Result<SplitConfig> {
    let mut cfg = SplitConfig::with_k(m.get_usize("k")?);
    if m.get_opt("strategy") == Some("rowwise") {
        cfg.strategy = Strategy::RowWise;
    }
    if m.flag("dynamic-k") {
        cfg.dynamic_k = Some(DynamicK::default());
    }
    Ok(cfg)
}

fn cmd_quantize(m: &Matches) -> Result<()> {
    let telemetry = Telemetry::from_matches(m)?;
    let ck = load_checkpoint(m.get("ckpt")?)?;
    let bits = parse_bits(m)?;
    let method = match m.get("method")? {
        "baseline" => Method::Baseline,
        "splitquant" => Method::SplitQuant(split_cfg(m)?),
        "ocs" => Method::Ocs {
            expand_ratio: m.get_f64("ocs-ratio")?,
        },
        other => bail!("unknown method '{other}'"),
    };
    let engine = splitquant::pipeline::Engine::new(m.get_usize("threads")?);
    log_info!(
        "quantizing {} ({} params) to {} via {} on {} pipeline workers",
        m.get("ckpt")?,
        human_count(splitquant::model::n_params(&ck.config) as u64),
        bits.name(),
        method.name(),
        engine.threads()
    );
    let (res, dur) =
        splitquant::util::timer::time_it(|| engine.quantize_model_reported(&ck, bits, &method));
    let (qm, report) = res?;
    qmodel::save_qmodel(m.get("out")?, &qm)?;
    println!(
        "{} → {} [{}] in {}   packed={}  (fp32 was {})",
        m.get("ckpt")?,
        m.get("out")?,
        qm.method_name,
        format_duration(dur),
        human_bytes(qm.packed_bytes()),
        human_bytes(ck.fp32_bytes()),
    );
    println!("{}", report.render());
    telemetry.finish()
}

fn cmd_eval(m: &Matches) -> Result<()> {
    let telemetry = Telemetry::from_matches(m)?;
    let mut spec = PipelineSpec::new(m.get("ckpt")?, m.get("problems")?);
    spec.use_runtime = m.flag("runtime");
    spec.engine = EngineKind::parse_cpu(m.get("engine")?)?;
    spec.kernel_impl = splitquant::kernels::KernelImpl::parse(m.get("kernel-impl")?)?;
    if spec.use_runtime && spec.engine == EngineKind::Packed {
        bail!("--engine packed cannot combine with --runtime (PJRT executes the batch); pick one");
    }
    if m.flag("no-amplify") {
        spec.amplify = None;
    } else {
        spec.amplify = Some((m.get_f64("amplify-frac")?, m.get_f64("amplify-gain")? as f32));
    }
    if let Some(dir) = m.get_opt("export-dir") {
        if !dir.is_empty() {
            std::fs::create_dir_all(dir)?;
            spec.out_dir = Some(PathBuf::from(dir));
        }
    }
    let mut coord = Coordinator::with_threads(m.get_usize("threads")?);
    if spec.use_runtime {
        coord.attach_engine("artifacts", None)?;
    }
    let ck = coord.load_model(&spec)?;
    let problems = coord.load_problems(&spec)?;

    let fp = coord.evaluate_fp(&ck, &problems, spec.use_runtime)?;
    if fp.n_errors > 0 {
        log_error!(
            "FP arm: {} problem(s) failed to score (first: {})",
            fp.n_errors,
            fp.first_error.as_deref().unwrap_or("unknown")
        );
    }
    let mut table = Table::new(&["arm", "accuracy", "d vs FP", "quantize", "packed"]);
    table.row(&[
        "Original (FP32)".to_string(),
        fp.accuracy_pct(),
        "-".into(),
        "-".into(),
        human_bytes(ck.fp32_bytes()),
    ]);
    let split = SplitConfig::with_k(m.get_usize("k")?);
    for arm in Coordinator::table1_arms(&split) {
        let res = coord.run_arm(&ck, &arm, &problems, &spec)?;
        table.row(&[
            res.label.clone(),
            res.report.accuracy_pct(),
            format!("{:+.2}%p", (res.report.accuracy - fp.accuracy) * 100.0),
            format_duration(res.quantize_time),
            human_bytes(res.packed_bytes),
        ]);
    }
    println!("{}", table.render());
    if m.flag("speculative") {
        eval_speculative(
            &ck,
            spec.engine,
            parse_draft_bits(m)?,
            m.get_usize("draft-k")?,
            &problems,
        )?;
    }
    println!("--- stage profile ---\n{}", coord.profiler.report());
    telemetry.finish()
}

/// `eval --speculative`: decode a handful of problem prompts plainly
/// and speculatively on the chosen CPU engine (packed INT8 target or
/// the f32 reference), assert the streams are bit-identical, and print
/// the acceptance rate plus the wall-clock speedup.
fn eval_speculative(
    ck: &splitquant::model::Checkpoint,
    engine: EngineKind,
    draft_bits: Bits,
    draft_k: usize,
    problems: &[splitquant::data::McqProblem],
) -> Result<()> {
    use splitquant::model::decode::DecodeState;
    use splitquant::model::forward::{generate_greedy, Workspace};
    use splitquant::model::packed::PackedModel;
    use splitquant::model::quantized::quantize_model;
    use splitquant::model::specdec::{SpecConfig, SpecDecoder, SpecStats};
    use std::time::Instant;

    let cfg = &ck.config;
    let dec = SpecDecoder::from_checkpoint(ck, draft_bits, SpecConfig { k: draft_k, adaptive: true })?;
    let mut ws = Workspace::new(cfg, cfg.max_seq);
    let mut dscratch = dec.draft_model().prewarmed_scratch();
    let n_new = 16usize;
    let prompts: Vec<&[usize]> = problems.iter().take(8).map(|p| p.prompt.as_slice()).collect();
    if prompts.is_empty() {
        bail!("--speculative needs at least one problem prompt");
    }
    let mut stats = SpecStats::default();
    let mut tokens = 0usize;
    let (plain_dur, spec_dur, target_name) = match engine {
        EngineKind::Packed => {
            let qm = quantize_model(ck, Bits::Int8, &Method::SplitQuant(SplitConfig::default()))?;
            let target = PackedModel::from_qmodel(&qm)?;
            let mut tscratch = target.prewarmed_scratch();
            let t0 = Instant::now();
            let mut plain = Vec::with_capacity(prompts.len());
            for p in &prompts {
                let mut st = DecodeState::new(cfg);
                plain.push(target.generate_greedy(p, n_new, &mut ws, &mut tscratch, &mut st)?);
            }
            let plain_dur = t0.elapsed();
            let t1 = Instant::now();
            for (p, want) in prompts.iter().zip(&plain) {
                let mut ts = DecodeState::new(cfg);
                let mut ds = DecodeState::new(cfg);
                let (got, s) = dec.generate_packed(
                    &target, p, n_new, &mut ws, &mut tscratch, &mut dscratch, &mut ts, &mut ds,
                )?;
                if &got != want {
                    bail!("speculative decode diverged from plain greedy (packed target)");
                }
                tokens += got.len();
                stats.merge(&s);
            }
            (plain_dur, t1.elapsed(), "INT8 packed")
        }
        EngineKind::Reference => {
            let t0 = Instant::now();
            let mut plain = Vec::with_capacity(prompts.len());
            for p in &prompts {
                plain.push(generate_greedy(ck, p, n_new, &mut ws)?);
            }
            let plain_dur = t0.elapsed();
            let t1 = Instant::now();
            for (p, want) in prompts.iter().zip(&plain) {
                let mut ts = DecodeState::new(cfg);
                let mut ds = DecodeState::new(cfg);
                let (got, s) =
                    dec.generate_reference(ck, p, n_new, &mut ws, &mut dscratch, &mut ts, &mut ds)?;
                if &got != want {
                    bail!("speculative decode diverged from plain greedy (reference target)");
                }
                tokens += got.len();
                stats.merge(&s);
            }
            (plain_dur, t1.elapsed(), "f32 reference")
        }
        EngineKind::Pjrt => bail!("--speculative needs a CPU engine (packed|reference)"),
    };
    let plain_tps = tokens as f64 / plain_dur.as_secs_f64();
    let spec_tps = tokens as f64 / spec_dur.as_secs_f64();
    println!(
        "--- speculative check [{} draft, k={draft_k}, {target_name} target] ---",
        draft_bits.name()
    );
    println!(
        "{} prompts x {n_new} tokens: bit-identical  acceptance {:.1}% ({}/{} drafted, {} rounds)",
        prompts.len(),
        100.0 * stats.acceptance_rate(),
        stats.accepted,
        stats.drafted,
        stats.rounds
    );
    println!(
        "plain {plain_tps:.0} tok/s -> speculative {spec_tps:.0} tok/s  ({:.2}x)",
        spec_tps / plain_tps
    );
    Ok(())
}

fn cmd_serve(m: &Matches) -> Result<()> {
    use splitquant::coordinator::server::{Backend, Server, ServerConfig};
    use std::time::Instant;

    let telemetry = Telemetry::from_matches(m)?;
    let bits = parse_bits(m)?;
    let ck = load_checkpoint(m.get("ckpt")?)?;
    let (problems, _) = splitquant::data::load_problems(m.get("problems")?)?;
    let n_requests = m.get_usize("requests")?.min(problems.len());

    let engine = splitquant::pipeline::Engine::new(m.get_usize("threads")?);
    let qm = engine.quantize_model(&ck, bits, &Method::SplitQuant(SplitConfig::default()))?;
    log_info!(
        "serving {} [{}] on the '{}' engine",
        m.get("ckpt")?,
        qm.method_name,
        m.get("engine")?
    );

    let kind = EngineKind::parse(m.get("engine")?)?;
    let backend = Backend::from_kind(kind, &qm, Some(Path::new(m.get("artifacts")?)))?;
    // Speculative decoding: pack a second, lower-bit draft of the same
    // checkpoint; the server verifies its proposals each decode step
    // (output stays bit-identical — DESIGN.md §11).
    let draft = if m.flag("speculative") {
        let draft_bits = parse_draft_bits(m)?;
        let dqm = engine.quantize_model(&ck, draft_bits, &Method::SplitQuant(SplitConfig::default()))?;
        log_info!(
            "speculative decoding on: {} draft, k = {}",
            draft_bits.name(),
            m.get("draft-k")?
        );
        Some(std::sync::Arc::new(
            splitquant::model::packed::PackedModel::from_qmodel(&dqm)?,
        ))
    } else {
        None
    };
    // Deterministic fault injection for chaos demos: arm the failpoint
    // plan from `SPLITQUANT_FAULTS` (seeded by `SPLITQUANT_FAULTS_SEED`)
    // before the server starts, so admission/forward/emit faults hit
    // from the first request.
    match splitquant::util::failpoint::FaultPlan::from_env() {
        Ok(Some(plan)) => {
            log_info!(
                "fault injection armed from SPLITQUANT_FAULTS: {} site(s), seed {}",
                plan.faults.len(),
                plan.seed
            );
            splitquant::util::failpoint::configure(plan);
        }
        Ok(None) => {}
        Err(e) => anyhow::bail!("bad SPLITQUANT_FAULTS: {e}"),
    }
    let deadline = m.get_ms("deadline-ms")?;
    let watchdog = m.get_ms("watchdog-ms")?;
    let config = ServerConfig::builder()
        .draft(draft)
        .draft_k(m.get_usize("draft-k")?)
        .max_wait(m.get_ms("max-wait-ms")?)
        .max_batch(m.get_usize("max-batch")?)
        .workers(m.get_usize("workers")?)
        .prefix_cache(m.get_usize("prefix-cache")?)
        .reuse_prefix(!m.flag("full-recompute"))
        .kernel_impl(splitquant::kernels::KernelImpl::parse(m.get("kernel-impl")?)?)
        .row_workers(m.get_usize("row-workers")?)
        .max_sessions(m.get_usize("max-sessions")?)
        .kv_blocks(m.get_usize("kv-blocks")?)
        .max_new_tokens(m.get_usize("max-new-tokens")?.max(1))
        .default_deadline((!deadline.is_zero()).then_some(deadline))
        .watchdog_step_budget((!watchdog.is_zero()).then_some(watchdog))
        .build()?;
    let max_new_tokens = config.max_new_tokens;
    let server = Server::start(backend, config)?;
    let drain_deadline = m.get_ms("drain-deadline-ms")?;

    if m.flag("stream") {
        serve_stream_demo(&server, &problems, n_requests, max_new_tokens)?;
        drain_and_report(&server, drain_deadline)?;
        return telemetry.finish();
    }

    let t0 = Instant::now();
    let mut rx = Vec::new();
    for p in problems.iter().take(n_requests) {
        rx.push(server.submit(p.clone()));
    }
    let mut correct = 0usize;
    let mut lat = Vec::new();
    let mut ttft = Vec::new();
    let mut batch_sizes = Vec::new();
    for r in rx {
        let resp = r.recv()??;
        if resp.result.is_correct() {
            correct += 1;
        }
        lat.push(resp.latency().as_secs_f64() * 1e3);
        ttft.push(resp.timing.ttft().as_secs_f64() * 1e3);
        batch_sizes.push(resp.batch_size as f64);
    }
    let wall = t0.elapsed();
    let s = splitquant::util::stats::Summary::of(&lat);
    let t = splitquant::util::stats::Summary::of(&ttft);
    println!(
        "served {n_requests} requests in {}  ({:.1} req/s)",
        format_duration(wall),
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "accuracy {:.2}%  latency p50 {:.1}ms p95 {:.1}ms  ttft p50 {:.1}ms  mean batch {:.1}",
        100.0 * correct as f64 / n_requests as f64,
        s.median,
        s.p95,
        t.median,
        splitquant::util::stats::Summary::of(&batch_sizes).mean
    );
    drain_and_report(&server, drain_deadline)?;
    telemetry.finish()
}

/// Gracefully drain the server and report what happened — the shutdown
/// step of every `serve` run (`--drain-deadline-ms` bounds how long
/// live sessions may keep decoding).
fn drain_and_report(
    server: &splitquant::coordinator::server::Server,
    deadline: std::time::Duration,
) -> Result<()> {
    let report = server.drain((!deadline.is_zero()).then_some(deadline))?;
    println!(
        "drained: {} completed, {} cancelled, {} shed; kv blocks in use: {}",
        report.completed, report.cancelled, report.shed, report.kv_blocks_in_use
    );
    Ok(())
}

/// `serve --stream`: fire one streaming generation per request (prompts
/// taken from the problem set), drain every token stream, and report
/// TTFT percentiles plus aggregate decode throughput.
fn serve_stream_demo(
    server: &splitquant::coordinator::server::Server,
    problems: &[splitquant::data::McqProblem],
    n_requests: usize,
    max_tokens: usize,
) -> Result<()> {
    use splitquant::coordinator::server::GenerateRequest;
    use std::time::Instant;

    let t0 = Instant::now();
    let streams: Vec<_> = (0..n_requests)
        .map(|i| {
            server.submit_generate(GenerateRequest {
                prompt: problems[i % problems.len()].prompt.clone(),
                max_tokens,
                deadline: None,
            })
        })
        .collect::<Result<_>>()?;
    let mut ttft = Vec::with_capacity(n_requests);
    let mut total_tokens = 0usize;
    let mut sample = Vec::new();
    for (i, s) in streams.into_iter().enumerate() {
        let done = s.wait()?;
        total_tokens += done.tokens.len();
        ttft.push(done.timing.ttft().as_secs_f64() * 1e3);
        if i == 0 {
            sample = done.tokens;
        }
    }
    let wall = t0.elapsed();
    let t = splitquant::util::stats::Summary::of(&ttft);
    println!(
        "streamed {n_requests} generations ({total_tokens} tokens) in {}  \
         ({:.0} tok/s)",
        format_duration(wall),
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "ttft p50 {:.2}ms p95 {:.2}ms  kv blocks in use after drain: {}",
        t.median,
        t.p95,
        server.kv_blocks_in_use()
    );
    println!("sample generation: {sample:?}");
    // With telemetry on, the speculative counters tell us how much of
    // the stream came from accepted draft tokens.
    if splitquant::obs::enabled() {
        let snap = splitquant::obs::snapshot();
        let drafted = snap
            .counter(splitquant::obs::names::SPECDEC_DRAFT_TOKENS)
            .unwrap_or(0);
        let accepted = snap
            .counter(splitquant::obs::names::SPECDEC_ACCEPTED_TOKENS)
            .unwrap_or(0);
        if drafted > 0 {
            println!(
                "speculative acceptance {:.1}%  ({accepted}/{drafted} draft tokens)",
                100.0 * accepted as f64 / drafted as f64
            );
        }
    }
    Ok(())
}

fn cmd_inspect(m: &Matches) -> Result<()> {
    let path = m.get("file")?;
    let c = read_file(path)?;
    println!("{} — {} tensors", path, c.names().len());
    for (k, v) in &c.meta {
        let v_short = if v.len() > 64 {
            format!("{}…", &v[..64])
        } else {
            v.clone()
        };
        println!("  meta {k} = {v_short}");
    }
    if let Some(cfg) = &c.config {
        println!("  config: {}", cfg.to_string());
    }
    let mut names = c.names();
    names.sort();
    for name in names.iter().take(50) {
        let (d, s, b) = c.raw(name)?;
        println!(
            "  {name:40} {} {:?} ({})",
            d.name(),
            s,
            human_bytes(b.len() as u64)
        );
    }
    if names.len() > 50 {
        println!("  … and {} more", names.len() - 50);
    }
    Ok(())
}

fn cmd_report(m: &Matches) -> Result<()> {
    let ck = load_checkpoint(m.get("ckpt")?)?;
    let bits = parse_bits(m)?;
    let cfg = SplitConfig::with_k(m.get_usize("k")?);
    let filter = m.get_opt("layer").filter(|s| !s.is_empty());
    let mut table = Table::new(&[
        "layer",
        "orig scale",
        "plane scales",
        "orig MSE",
        "split MSE",
        "gain",
    ]);
    for info in param_inventory(&ck.config) {
        if info.kind != ParamKind::Linear {
            continue;
        }
        if let Some(f) = filter {
            if info.name != f {
                continue;
            }
        }
        let w = ck.get(&info.name)?;
        let rep = splitquant::split::resolution_report(w, &cfg, bits);
        table.row(&[
            info.name.clone(),
            format!("{:.1}", rep.original_scale),
            rep.plane_scales
                .iter()
                .map(|s| format!("{s:.1}"))
                .collect::<Vec<_>>()
                .join("/"),
            format!("{:.2e}", rep.original_mse),
            format!("{:.2e}", rep.split_mse),
            format!("{:.1}x", rep.mse_gain),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn main() {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let m = match app.parse(&argv) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Some(level) = m.get_opt("log").and_then(logging::Level::parse) {
        logging::set_level(level);
    }
    let result = match m.command {
        "quantize" => cmd_quantize(&m),
        "eval" => cmd_eval(&m),
        "serve" => cmd_serve(&m),
        "inspect" => cmd_inspect(&m),
        "report" => cmd_report(&m),
        other => Err(anyhow::anyhow!("unhandled command {other}")),
    };
    if let Err(e) = result {
        log_error!("{e:#}");
        std::process::exit(1);
    }
}
