//! MCQ scoring through the PJRT runtime: assembles weight arguments for
//! the exported variants, batches the problem set, and returns choices.
//!
//! Variant/arm mapping:
//! * FP checkpoint            → `score_fp`
//! * Baseline linear quant    → `score_quant_k1` (one int8 plane/linear)
//! * SplitQuantV2 (k=3)       → `score_quant_k3` (stacked planes)
//! * GPTQ (per-channel) / OCS → `score_fp` on the *effective* checkpoint
//!   (their grids are not per-tensor, so the int-plane executable does
//!   not apply; numerics are identical by construction).
//!
//! Options in the synthetic-arc set are single tokens, so ranking
//! continuation likelihood reduces to comparing last-position logits at
//! the option token ids (softmax is monotone).

use std::collections::BTreeMap;

use crate::data::McqProblem;
use crate::eval::{EvalReport, ProblemResult};
use crate::model::quantized::{QuantParam, QuantizedModel};
use crate::model::Checkpoint;

use super::{ArgValue, Engine};
use anyhow::{bail, Result};

/// Weight arguments for `score_fp`.
pub fn fp_args(ck: &Checkpoint) -> BTreeMap<String, ArgValue> {
    ck.tensors
        .iter()
        .map(|(name, t)| (name.clone(), ArgValue::F32(t.data().to_vec())))
        .collect()
}

/// Weight arguments for `score_quant_k{k}` from a quantized model whose
/// linears are per-tensor planes (baseline k=1 or split k=3).
///
/// Layers whose effective plane count is below `k` (degenerate splits)
/// are padded with zero planes (scale 1, zp 0 → dequantizes to 0).
pub fn quant_args(qm: &QuantizedModel, k: usize) -> Result<BTreeMap<String, ArgValue>> {
    let mut args = BTreeMap::new();
    // Dequantized embedding doubles as the tied LM head.
    args.insert(
        "embed.tok".to_string(),
        ArgValue::F32(qm.embedding.dequantize().data().to_vec()),
    );
    for (name, t) in &qm.fp_tensors {
        args.insert(name.clone(), ArgValue::F32(t.data().to_vec()));
    }
    for (name, qp) in &qm.linears {
        let (planes, scales, zps): (Vec<&[i8]>, Vec<f32>, Vec<f32>) = match qp {
            QuantParam::Plain(q) => {
                if q.params.len() != 1 {
                    bail!("'{name}' is per-channel; use the effective-checkpoint path");
                }
                (
                    vec![q.plane.data()],
                    vec![q.params[0].scale as f32],
                    vec![q.params[0].zero_point as f32],
                )
            }
            QuantParam::Split(s) => (
                s.planes.iter().map(|p| p.plane.data()).collect(),
                s.planes.iter().map(|p| p.params[0].scale as f32).collect(),
                s.planes
                    .iter()
                    .map(|p| p.params[0].zero_point as f32)
                    .collect(),
            ),
            QuantParam::OcsEffective { .. } => {
                bail!("'{name}' is OCS-effective; use the effective-checkpoint path")
            }
        };
        if planes.len() > k {
            bail!("'{name}' has {} planes > variant k={k}", planes.len());
        }
        let numel = planes[0].len();
        let mut stacked: Vec<i8> = Vec::with_capacity(k * numel);
        let mut s_out = Vec::with_capacity(k);
        let mut z_out = Vec::with_capacity(k);
        for (i, p) in planes.iter().enumerate() {
            stacked.extend_from_slice(p);
            s_out.push(scales[i]);
            z_out.push(zps[i]);
        }
        for _ in planes.len()..k {
            stacked.extend(std::iter::repeat(0i8).take(numel));
            s_out.push(1.0);
            z_out.push(0.0);
        }
        args.insert(format!("{name}.planes"), ArgValue::I8(stacked));
        args.insert(format!("{name}.scales"), ArgValue::F32(s_out));
        args.insert(format!("{name}.zps"), ArgValue::F32(z_out));
    }
    Ok(args)
}

/// Check that a quantized model is runnable through an int-plane variant.
pub fn is_int_plane_compatible(qm: &QuantizedModel) -> bool {
    qm.linears.values().all(|qp| match qp {
        QuantParam::Plain(q) => q.params.len() == 1,
        QuantParam::Split(_) => true,
        QuantParam::OcsEffective { .. } => false,
    })
}

/// Max plane count across linears (→ which variant to use).
pub fn plane_count(qm: &QuantizedModel) -> usize {
    qm.linears.values().map(|q| q.n_planes()).max().unwrap_or(1)
}

/// Score a problem set through a variant. `weight_args` are the
/// non-token arguments; prompts are batched to the manifest batch size
/// (last batch padded by repetition).
pub fn score_problems(
    engine: &Engine,
    variant: &str,
    weight_args: &BTreeMap<String, ArgValue>,
    problems: &[McqProblem],
) -> Result<EvalReport> {
    let b = engine.batch;
    let plen = engine.prompt_len;
    let mut results = Vec::with_capacity(problems.len());
    for chunk in problems.chunks(b) {
        let mut tokens = Vec::with_capacity(b * plen);
        for p in chunk {
            if p.prompt.len() != plen {
                bail!("prompt length {} != exported {plen}", p.prompt.len());
            }
            tokens.extend(p.prompt.iter().map(|&t| t as i32));
        }
        // Pad the final chunk by repeating the first prompt.
        for _ in chunk.len()..b {
            tokens.extend(chunk[0].prompt.iter().map(|&t| t as i32));
        }
        let mut args = weight_args.clone();
        args.insert("tokens".to_string(), ArgValue::I32(tokens));
        let logits = engine.execute(variant, &args)?; // [B, vocab]
        let vocab = logits.shape()[1];
        for (i, p) in chunk.iter().enumerate() {
            let row = logits.row(i);
            let mut lps = Vec::with_capacity(p.options.len());
            for opt in &p.options {
                if opt.len() != 1 {
                    bail!("multi-token options need the CPU scoring path");
                }
                if opt[0] >= vocab {
                    bail!("option token {} out of vocab {vocab}", opt[0]);
                }
                lps.push(crate::model::forward::log_prob(row, opt[0]));
            }
            // NaN logprobs rank as -inf instead of panicking the caller.
            let chosen = crate::eval::nan_safe_argmax(&lps);
            results.push(ProblemResult {
                chosen,
                correct: p.correct,
                logprobs: lps,
            });
        }
    }
    Ok(EvalReport::from_results(&results))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::quantized::{quantize_model, Method};
    use crate::model::PicoLlamaConfig;
    use crate::quant::Bits;
    use crate::split::SplitConfig;

    #[test]
    fn quant_args_shapes() {
        let ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 1);
        let qm = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
            .unwrap();
        let args = quant_args(&qm, 3).unwrap();
        // Every linear contributes 3 args; embedding + norms present.
        let n_linear = ck
            .tensors
            .keys()
            .filter(|k| k.contains("attn.") || k.contains("mlp."))
            .count();
        assert_eq!(
            args.len(),
            1 + qm.fp_tensors.len() + 3 * n_linear,
            "arg count"
        );
        let ArgValue::I8(p) = &args["layers.0.attn.wq.planes"] else {
            panic!("planes must be i8");
        };
        let d = ck.config.d_model;
        assert_eq!(p.len(), 3 * d * d);
        assert!(is_int_plane_compatible(&qm));
        assert_eq!(plane_count(&qm), 3);
    }

    #[test]
    fn quant_args_pads_degenerate_layers() {
        let ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 2);
        // Baseline (1 plane) padded up to k=3 must dequantize identically.
        let qm = quantize_model(&ck, Bits::Int8, &Method::Baseline).unwrap();
        let args = quant_args(&qm, 3).unwrap();
        let ArgValue::F32(scales) = &args["layers.0.attn.wq.scales"] else {
            panic!()
        };
        assert_eq!(scales.len(), 3);
        assert_eq!(scales[1], 1.0);
        let ArgValue::F32(zps) = &args["layers.0.attn.wq.zps"] else {
            panic!()
        };
        assert_eq!(zps[2], 0.0);
    }

    #[test]
    fn ocs_rejected_from_int_path() {
        let ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 3);
        let qm = quantize_model(&ck, Bits::Int4, &Method::Ocs { expand_ratio: 0.05 }).unwrap();
        assert!(!is_int_plane_compatible(&qm));
        assert!(quant_args(&qm, 1).is_err());
    }
}
