//! PJRT runtime: loads the AOT-lowered HLO text from `artifacts/`,
//! compiles each variant once on the CPU PJRT client, and executes them
//! from the rust request path. Python never runs here.
//!
//! The contract with `python/compile/aot.py` is `manifest.json`: each
//! variant lists its HLO file and the ordered argument specs (name,
//! dtype, shape). [`Engine::execute`] takes a name→tensor map, assembles
//! the positional literals, runs, and returns the f32 output.

pub mod scoring;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Which execution engine runs a model — the one selector shared by
/// the CLI, the pipeline coordinator and the scoring/generation server.
/// (Previously three overlapping types — `runtime::Engine` loading,
/// `coordinator::server::Backend` construction and the pipeline-side
/// `ExecEngine` — each re-matched the same strings; they now all parse
/// through here.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Dequantize to an effective f32 checkpoint and run the CPU
    /// reference forward (simulated quantization — full f32 bandwidth).
    Reference,
    /// Run straight on the bit-packed planes through the
    /// [`crate::kernels`] engine (no f32 weight matrices materialized).
    Packed,
    /// AOT-compiled PJRT artifacts executed by [`Engine`].
    Pjrt,
}

impl EngineKind {
    /// Parse a CLI `--engine` value.
    pub fn parse(s: &str) -> Result<EngineKind> {
        Ok(match s {
            "reference" => EngineKind::Reference,
            "packed" => EngineKind::Packed,
            "pjrt" => EngineKind::Pjrt,
            other => bail!("unknown engine '{other}' (use packed|reference|pjrt)"),
        })
    }

    /// Parse restricted to the CPU engines — the pipeline path, which
    /// routes PJRT through its separate `--runtime` flag instead.
    pub fn parse_cpu(s: &str) -> Result<EngineKind> {
        let kind = EngineKind::parse(s)?;
        if !kind.is_cpu() {
            bail!("engine '{}' is not a CPU engine here (use packed|reference)", kind.name());
        }
        Ok(kind)
    }

    /// Whether this engine executes on the CPU forward paths (vs PJRT).
    pub fn is_cpu(self) -> bool {
        !matches!(self, EngineKind::Pjrt)
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Reference => "reference",
            EngineKind::Packed => "packed",
            EngineKind::Pjrt => "pjrt",
        }
    }
}

/// Argument spec from the manifest.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i8" | "i32"
}

/// One compiled variant.
pub struct Variant {
    pub name: String,
    pub args: Vec<ArgSpec>,
    pub out_shape: Vec<usize>,
    exe: xla::PjRtLoadedExecutable,
}

/// A runtime argument value.
#[derive(Clone, Debug)]
pub enum ArgValue {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
}

impl ArgValue {
    pub fn from_tensor(t: &Tensor) -> ArgValue {
        ArgValue::F32(t.data().to_vec())
    }

    fn len(&self) -> usize {
        match self {
            ArgValue::F32(v) => v.len(),
            ArgValue::I8(v) => v.len(),
            ArgValue::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            ArgValue::F32(_) => "f32",
            ArgValue::I8(_) => "i8",
            ArgValue::I32(_) => "i32",
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            ArgValue::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            ArgValue::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            ArgValue::I8(v) => {
                // The crate's `vec1` NativeType set excludes i8; build an
                // S8 literal of the right shape and copy raw bytes in.
                let mut lit =
                    xla::Literal::create_from_shape(xla::PrimitiveType::S8, shape);
                lit.copy_raw_from(v)
                    .map_err(|e| anyhow!("copying i8 literal: {e:?}"))?;
                lit
            }
        };
        Ok(lit)
    }
}

/// The PJRT engine: client + compiled variants.
pub struct Engine {
    client: xla::PjRtClient,
    variants: BTreeMap<String, Variant>,
    pub artifacts_dir: PathBuf,
    pub batch: usize,
    pub prompt_len: usize,
}

impl Engine {
    /// Load `manifest.json` and compile every variant (or a subset).
    pub fn load(artifacts_dir: impl AsRef<Path>, only: Option<&[&str]>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&text)?;
        if manifest.req("format")?.as_str() != Some("splitquant-artifacts-v1") {
            bail!("unexpected manifest format");
        }
        let batch = manifest.req("batch")?.as_usize().unwrap_or(32);
        let prompt_len = manifest.req("prompt_len")?.as_usize().unwrap_or(3);

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut variants = BTreeMap::new();
        for (name, spec) in manifest
            .req("variants")?
            .as_obj()
            .ok_or_else(|| anyhow!("bad variants"))?
        {
            if let Some(filter) = only {
                if !filter.contains(&name.as_str()) {
                    continue;
                }
            }
            let file = spec.req("file")?.as_str().unwrap_or_default();
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            let mut args = Vec::new();
            for aj in spec
                .req("args")?
                .as_arr()
                .ok_or_else(|| anyhow!("bad args"))?
            {
                args.push(ArgSpec {
                    name: aj.req("name")?.as_str().unwrap_or_default().to_string(),
                    shape: aj
                        .req("shape")?
                        .as_usize_vec()
                        .ok_or_else(|| anyhow!("bad shape"))?,
                    dtype: aj.req("dtype")?.as_str().unwrap_or_default().to_string(),
                });
            }
            let out_shape = spec
                .req("out_shape")?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("bad out_shape"))?;
            variants.insert(
                name.clone(),
                Variant {
                    name: name.clone(),
                    args,
                    out_shape,
                    exe,
                },
            );
        }
        Ok(Engine {
            client,
            variants,
            artifacts_dir: dir,
            batch,
            prompt_len,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("variant '{name}' not loaded"))
    }

    /// Execute a variant with named arguments. Returns the f32 output
    /// tensor shaped per the manifest.
    pub fn execute(&self, name: &str, args: &BTreeMap<String, ArgValue>) -> Result<Tensor> {
        let var = self.variant(name)?;
        let mut literals = Vec::with_capacity(var.args.len());
        for spec in &var.args {
            let val = args
                .get(&spec.name)
                .ok_or_else(|| anyhow!("missing argument '{}' for {name}", spec.name))?;
            let numel: usize = spec.shape.iter().product();
            if val.len() != numel {
                bail!(
                    "argument '{}': {} values, shape {:?} needs {numel}",
                    spec.name,
                    val.len(),
                    spec.shape
                );
            }
            if val.dtype() != spec.dtype {
                bail!(
                    "argument '{}': dtype {} != manifest {}",
                    spec.name,
                    val.dtype(),
                    spec.dtype
                );
            }
            literals.push(val.to_literal(&spec.shape)?);
        }
        let result = var
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untupling result: {e:?}"))?;
        let values = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("result to f32: {e:?}"))?;
        Ok(Tensor::new(&var.out_shape, values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parses_and_rejects() {
        assert_eq!(EngineKind::parse("packed").unwrap(), EngineKind::Packed);
        assert_eq!(EngineKind::parse("reference").unwrap(), EngineKind::Reference);
        assert_eq!(EngineKind::parse("pjrt").unwrap(), EngineKind::Pjrt);
        assert!(EngineKind::parse("gpu").is_err());
        assert!(EngineKind::parse_cpu("pjrt").is_err(), "pipeline path is CPU-only");
        assert_eq!(EngineKind::parse_cpu("packed").unwrap().name(), "packed");
        assert!(!EngineKind::Pjrt.is_cpu());
    }

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn engine_loads_micro_variant() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = Engine::load(&dir, Some(&["linear_micro_k3"])).unwrap();
        assert_eq!(eng.variant_names(), vec!["linear_micro_k3"]);
        assert!(eng.platform().to_lowercase().contains("cpu") || !eng.platform().is_empty());
    }

    #[test]
    fn micro_kernel_matches_cpu_reference() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = Engine::load(&dir, Some(&["linear_micro_k3"])).unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let (m, n, k) = (128usize, 128usize, 128usize);
        let mut x = vec![0.0f32; m * k];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let planes: Vec<i8> = (0..3 * n * k)
            .map(|_| (rng.below(16) as i32 - 8) as i8)
            .collect();
        let scales = vec![4.0f32, 1.5, 0.5];
        let zps = vec![-2.0f32, 0.0, 3.0];

        let mut args = BTreeMap::new();
        args.insert("x".to_string(), ArgValue::F32(x.clone()));
        args.insert("planes".to_string(), ArgValue::I8(planes.clone()));
        args.insert("scales".to_string(), ArgValue::F32(scales.clone()));
        args.insert("zps".to_string(), ArgValue::F32(zps.clone()));
        let got = eng.execute("linear_micro_k3", &args).unwrap();

        // CPU reference: y = Σ_j x · dequant(plane_j)ᵀ.
        let xt = Tensor::new(&[m, k], x);
        let mut want = Tensor::zeros(&[m, n]);
        for j in 0..3 {
            let w: Vec<f32> = planes[j * n * k..(j + 1) * n * k]
                .iter()
                .map(|&q| (q as f32 - zps[j]) / scales[j])
                .collect();
            let wt = Tensor::new(&[n, k], w);
            want.add_assign(&crate::tensor::matmul(&xt, &wt.transpose()));
        }
        assert!(
            got.allclose(&want, 2e-2),
            "max diff {}",
            crate::util::stats::max_abs_diff(got.data(), want.data())
        );
    }

    #[test]
    fn execute_validates_args() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = Engine::load(&dir, Some(&["linear_micro_k3"])).unwrap();
        // Missing args.
        let err = eng.execute("linear_micro_k3", &BTreeMap::new());
        assert!(err.is_err());
        // Wrong shape.
        let mut args = BTreeMap::new();
        args.insert("x".to_string(), ArgValue::F32(vec![0.0; 3]));
        assert!(eng.execute("linear_micro_k3", &args).is_err());
        // Unknown variant.
        assert!(eng.execute("nope", &BTreeMap::new()).is_err());
    }
}
