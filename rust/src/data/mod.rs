//! Synthetic-ARC: the evaluation workload substituting the paper's ARC
//! Challenge set (DESIGN.md §3).
//!
//! A seeded "fact world" maps (entity, attribute) → value. The model is
//! trained (in JAX, build time) on statements `<bos> e a v <eos>`; the
//! evaluation presents 4-choice problems — prompt `<bos> e a`, options =
//! {correct value, 3 distractors} — scored by max continuation
//! likelihood, the same rule Meta's ARC harness uses for Llama 3.2.
//!
//! The generator lives in *both* languages: `python/compile/datagen.py`
//! produces the training corpus + the canonical 1165-problem eval set
//! consumed via `artifacts/`; this module generates structurally
//! identical worlds for Rust-native tests and benches, and loads the
//! canonical problem set (JSON) for the Table-1 harness.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};

/// Fixed special tokens (ids 0..=4). Entity/attr/value tokens follow.
pub const PAD: usize = 0;
pub const BOS: usize = 1;
pub const EOS: usize = 2;
pub const SEP: usize = 3;
pub const QMARK: usize = 4;
pub const N_SPECIAL: usize = 5;

/// A deterministic fact world.
#[derive(Clone, Debug)]
pub struct FactWorld {
    pub n_entities: usize,
    pub n_attrs: usize,
    pub n_values: usize,
    /// facts[e * n_attrs + a] = value index.
    pub facts: Vec<usize>,
    pub seed: u64,
}

impl FactWorld {
    pub fn generate(n_entities: usize, n_attrs: usize, n_values: usize, seed: u64) -> FactWorld {
        assert!(n_values >= 4, "need ≥4 values for 4-choice MCQ");
        let mut rng = Rng::new(seed);
        let facts = (0..n_entities * n_attrs)
            .map(|_| rng.below(n_values))
            .collect();
        FactWorld {
            n_entities,
            n_attrs,
            n_values,
            facts,
            seed,
        }
    }

    pub fn value_of(&self, entity: usize, attr: usize) -> usize {
        self.facts[entity * self.n_attrs + attr]
    }

    /// Vocabulary size implied by this world.
    pub fn vocab_size(&self) -> usize {
        N_SPECIAL + self.n_entities + self.n_attrs + self.n_values
    }

    pub fn entity_token(&self, e: usize) -> usize {
        N_SPECIAL + e
    }

    pub fn attr_token(&self, a: usize) -> usize {
        N_SPECIAL + self.n_entities + a
    }

    pub fn value_token(&self, v: usize) -> usize {
        N_SPECIAL + self.n_entities + self.n_attrs + v
    }

    /// One training statement: `<bos> e a v <eos>`.
    pub fn statement(&self, entity: usize, attr: usize) -> Vec<usize> {
        vec![
            BOS,
            self.entity_token(entity),
            self.attr_token(attr),
            self.value_token(self.value_of(entity, attr)),
            EOS,
        ]
    }

    /// Training corpus: every fact stated `repeats` times, shuffled.
    pub fn corpus(&self, repeats: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(self.n_entities * self.n_attrs * repeats);
        for _ in 0..repeats {
            for e in 0..self.n_entities {
                for a in 0..self.n_attrs {
                    out.push(self.statement(e, a));
                }
            }
        }
        rng.shuffle(&mut out);
        out
    }
}

/// One 4-choice problem.
#[derive(Clone, Debug, PartialEq)]
pub struct McqProblem {
    /// Teacher-forced prompt, e.g. `<bos> e a`.
    pub prompt: Vec<usize>,
    /// Option continuations (single value token each).
    pub options: Vec<Vec<usize>>,
    /// Index of the correct option in `options`.
    pub correct: usize,
}

/// Generate `n` problems (mirrors the ARC set's 1165) with 3 distractor
/// values per question, deterministic in `seed`.
pub fn generate_problems(world: &FactWorld, n: usize, seed: u64) -> Vec<McqProblem> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let e = rng.below(world.n_entities);
        let a = rng.below(world.n_attrs);
        let v = world.value_of(e, a);
        // 3 distinct distractors ≠ v.
        let mut opts = vec![v];
        while opts.len() < 4 {
            let d = rng.below(world.n_values);
            if !opts.contains(&d) {
                opts.push(d);
            }
        }
        rng.shuffle(&mut opts);
        let correct = opts.iter().position(|&x| x == v).unwrap();
        out.push(McqProblem {
            prompt: vec![BOS, world.entity_token(e), world.attr_token(a)],
            options: opts
                .iter()
                .map(|&o| vec![world.value_token(o)])
                .collect(),
            correct,
        });
    }
    out
}

// ---------------------------------------------------------------------------
// JSON interchange with python/compile/datagen.py
// ---------------------------------------------------------------------------

fn tokens_json(toks: &[usize]) -> Json {
    Json::usizes(toks)
}

fn tokens_from_json(j: &Json) -> Result<Vec<usize>> {
    j.as_usize_vec()
        .ok_or_else(|| anyhow!("expected token array"))
}

/// Serialize problems to the canonical JSON format.
pub fn problems_to_json(problems: &[McqProblem], vocab_size: usize) -> Json {
    Json::obj(vec![
        ("format", Json::str("synthetic-arc-v1")),
        ("vocab_size", Json::num(vocab_size as f64)),
        (
            "problems",
            Json::Arr(
                problems
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("prompt", tokens_json(&p.prompt)),
                            (
                                "options",
                                Json::Arr(p.options.iter().map(|o| tokens_json(o)).collect()),
                            ),
                            ("correct", Json::num(p.correct as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parse the canonical JSON format. Returns (problems, vocab_size).
pub fn problems_from_json(j: &Json) -> Result<(Vec<McqProblem>, usize)> {
    match j.get("format").and_then(|f| f.as_str()) {
        Some("synthetic-arc-v1") => {}
        other => bail!("unknown problems format {other:?}"),
    }
    let vocab_size = j
        .req("vocab_size")?
        .as_usize()
        .ok_or_else(|| anyhow!("bad vocab_size"))?;
    let mut problems = Vec::new();
    for pj in j
        .req("problems")?
        .as_arr()
        .ok_or_else(|| anyhow!("problems not an array"))?
    {
        let prompt = tokens_from_json(pj.req("prompt")?)?;
        let options = pj
            .req("options")?
            .as_arr()
            .ok_or_else(|| anyhow!("options not an array"))?
            .iter()
            .map(tokens_from_json)
            .collect::<Result<Vec<_>>>()?;
        let correct = pj
            .req("correct")?
            .as_usize()
            .ok_or_else(|| anyhow!("bad correct index"))?;
        if correct >= options.len() {
            bail!("correct index {correct} out of range");
        }
        if options.iter().any(|o| o.is_empty()) {
            bail!("empty option continuation");
        }
        problems.push(McqProblem {
            prompt,
            options,
            correct,
        });
    }
    Ok((problems, vocab_size))
}

/// Load problems from a JSON file (as written by datagen.py or this crate).
pub fn load_problems(path: impl AsRef<Path>) -> Result<(Vec<McqProblem>, usize)> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| anyhow!("reading {}: {e}", path.as_ref().display()))?;
    problems_from_json(&Json::parse(&text)?)
}

/// Save problems to a JSON file.
pub fn save_problems(
    path: impl AsRef<Path>,
    problems: &[McqProblem],
    vocab_size: usize,
) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(
        path,
        problems_to_json(problems, vocab_size).to_string_pretty(),
    )?;
    Ok(())
}

/// Human-readable token name (debugging / the INT2 text probe).
pub fn token_name(world: &FactWorld, tok: usize) -> String {
    match tok {
        PAD => "<pad>".into(),
        BOS => "<bos>".into(),
        EOS => "<eos>".into(),
        SEP => "<sep>".into(),
        QMARK => "<?>".into(),
        t if t < N_SPECIAL + world.n_entities => format!("e{}", t - N_SPECIAL),
        t if t < N_SPECIAL + world.n_entities + world.n_attrs => {
            format!("a{}", t - N_SPECIAL - world.n_entities)
        }
        t if t < world.vocab_size() => {
            format!("v{}", t - N_SPECIAL - world.n_entities - world.n_attrs)
        }
        t => format!("<unk{t}>"),
    }
}

/// Summary of a problem set (for reports).
pub fn problem_stats(problems: &[McqProblem]) -> BTreeMap<String, f64> {
    let mut m = BTreeMap::new();
    m.insert("n_problems".into(), problems.len() as f64);
    let avg_prompt =
        problems.iter().map(|p| p.prompt.len()).sum::<usize>() as f64 / problems.len() as f64;
    m.insert("avg_prompt_len".into(), avg_prompt);
    let n_opts =
        problems.iter().map(|p| p.options.len()).sum::<usize>() as f64 / problems.len() as f64;
    m.insert("avg_options".into(), n_opts);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> FactWorld {
        FactWorld::generate(20, 5, 10, 42)
    }

    #[test]
    fn world_is_deterministic() {
        let a = FactWorld::generate(10, 4, 8, 7);
        let b = FactWorld::generate(10, 4, 8, 7);
        assert_eq!(a.facts, b.facts);
    }

    #[test]
    fn token_spaces_disjoint() {
        let w = world();
        let e = w.entity_token(w.n_entities - 1);
        let a = w.attr_token(0);
        let v = w.value_token(0);
        assert!(e < a && a < v);
        assert!(w.value_token(w.n_values - 1) == w.vocab_size() - 1);
    }

    #[test]
    fn statements_encode_facts() {
        let w = world();
        let s = w.statement(3, 2);
        assert_eq!(s.len(), 5);
        assert_eq!(s[0], BOS);
        assert_eq!(s[4], EOS);
        assert_eq!(s[3], w.value_token(w.value_of(3, 2)));
    }

    #[test]
    fn corpus_covers_all_facts() {
        let w = world();
        let c = w.corpus(2, 1);
        assert_eq!(c.len(), 2 * w.n_entities * w.n_attrs);
        // Every fact appears exactly twice.
        let mut counts = BTreeMap::new();
        for s in &c {
            *counts.entry((s[1], s[2])).or_insert(0usize) += 1;
        }
        assert!(counts.values().all(|&n| n == 2));
    }

    #[test]
    fn problems_have_valid_structure() {
        let w = world();
        let ps = generate_problems(&w, 100, 3);
        assert_eq!(ps.len(), 100);
        for p in &ps {
            assert_eq!(p.options.len(), 4);
            assert!(p.correct < 4);
            // Options distinct.
            let mut o = p.options.clone();
            o.sort();
            o.dedup();
            assert_eq!(o.len(), 4);
            // The correct option matches the world's fact.
            let e = p.prompt[1] - N_SPECIAL;
            let a = p.prompt[2] - N_SPECIAL - w.n_entities;
            let v = w.value_of(e, a);
            assert_eq!(p.options[p.correct][0], w.value_token(v));
        }
    }

    #[test]
    fn json_roundtrip() {
        let w = world();
        let ps = generate_problems(&w, 25, 9);
        let j = problems_to_json(&ps, w.vocab_size());
        let (back, vs) = problems_from_json(&j).unwrap();
        assert_eq!(vs, w.vocab_size());
        assert_eq!(back, ps);
    }

    #[test]
    fn file_roundtrip() {
        let w = world();
        let ps = generate_problems(&w, 10, 9);
        let dir = std::env::temp_dir().join("sq_problems");
        let path = dir.join("p.json");
        save_problems(&path, &ps, w.vocab_size()).unwrap();
        let (back, _) = load_problems(&path).unwrap();
        assert_eq!(back, ps);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_json() {
        for bad in [
            r#"{"format":"nope","vocab_size":5,"problems":[]}"#,
            r#"{"format":"synthetic-arc-v1","problems":[]}"#,
            r#"{"format":"synthetic-arc-v1","vocab_size":5,"problems":[{"prompt":[1],"options":[[2]],"correct":3}]}"#,
        ] {
            assert!(
                problems_from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn token_names_cover_vocab() {
        let w = world();
        for t in 0..w.vocab_size() {
            let name = token_name(&w, t);
            assert!(!name.starts_with("<unk"), "token {t} => {name}");
        }
        assert!(token_name(&w, w.vocab_size()).starts_with("<unk"));
    }
}
