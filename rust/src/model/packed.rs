//! PackedForward: the packed-integer execution path of picollama.
//!
//! [`PackedModel`] holds the deployable artifact's *actual* bytes —
//! bit-packed INT2/4/8 planes straight out of `io/qmodel.rs` — and runs
//! the full forward through the [`crate::kernels`] engine: every linear
//! layer and the embedding/LM-head execute on packed planes; RMSNorm,
//! RoPE, attention and SwiGLU stay f32 (shared verbatim with the
//! reference forward via [`super::forward::ForwardOps`]). No f32 weight
//! matrix is ever materialized, so a forward streams the packed bytes
//! (INT4: 1/8 of the f32 weight traffic per plane) instead of
//! full-precision dequants.
//!
//! Functional equivalence: masked zeros in split planes unpack to an
//! exact 0 contribution and plane outputs are accumulated per cluster
//! scale, so logits match the dequantize-then-f32 reference within FP
//! summation-order tolerance (property-tested in
//! `rust/tests/packed_kernels.rs`).

use std::collections::BTreeMap;

use crate::kernels::{self, KernelScratch, PackedLinear, PackedMatrix};
use crate::model::decode::DecodeState;
use crate::model::forward::{
    continuation_logprob_from_logits, forward_extend, forward_ops, option_logprobs, prompt_pass,
    ForwardOps, Workspace,
};
use crate::model::quantized::{QuantParam, QuantizedModel};
use crate::model::PicoLlamaConfig;
use crate::quant::Bits;
use crate::tensor::Tensor;

use anyhow::{anyhow, Result};

/// Convert one quantized linear parameter into its packed kernel form:
/// plain → 1 plane, split → k planes, OCS → dense f32 fallback (its
/// expansion is virtual; there is no integer-plane form to execute).
pub fn pack_linear(qp: &QuantParam) -> Result<PackedLinear> {
    match qp {
        QuantParam::Plain(q) => PackedLinear::from_planes(vec![PackedMatrix::from_quantized(q)?]),
        QuantParam::Split(s) => PackedLinear::from_planes(
            s.planes
                .iter()
                .map(PackedMatrix::from_quantized)
                .collect::<Result<Vec<_>>>()?,
        ),
        QuantParam::OcsEffective { effective, .. } => PackedLinear::dense(effective.clone()),
    }
}

/// A quantized model in executable packed form.
#[derive(Clone, Debug)]
pub struct PackedModel {
    pub config: PicoLlamaConfig,
    pub bits: Bits,
    pub method_name: String,
    linears: BTreeMap<String, PackedLinear>,
    embedding: PackedMatrix,
    fp_tensors: BTreeMap<String, Tensor>,
}

impl PackedModel {
    /// Pack every linear + the embedding of a quantized model. Works for
    /// all methods (baseline, SplitQuantV2, per-channel GPTQ grids, OCS
    /// via the dense fallback).
    pub fn from_qmodel(qm: &QuantizedModel) -> Result<PackedModel> {
        let mut linears = BTreeMap::new();
        for (name, qp) in &qm.linears {
            let lin = pack_linear(qp).map_err(|e| anyhow!("packing '{name}': {e}"))?;
            linears.insert(name.clone(), lin);
        }
        Ok(PackedModel {
            config: qm.config.clone(),
            bits: qm.bits,
            method_name: qm.method_name.clone(),
            linears,
            embedding: PackedMatrix::from_quantized(&qm.embedding)?,
            fp_tensors: qm.fp_tensors.clone(),
        })
    }

    /// Full forward on packed weights: token ids → logits `[seq, vocab]`.
    /// Convenience wrapper allocating a fresh kernel scratch; hot paths
    /// should hold a [`KernelScratch`] and call [`Self::forward_with`].
    pub fn forward(&self, tokens: &[usize], ws: &mut Workspace) -> Result<Tensor> {
        self.forward_with(tokens, ws, &mut KernelScratch::new())
    }

    /// Full forward reusing the caller's kernel scratch (buffers grow to
    /// the largest layer once and stay).
    pub fn forward_with(
        &self,
        tokens: &[usize],
        ws: &mut Workspace,
        scratch: &mut KernelScratch,
    ) -> Result<Tensor> {
        let mut ops = PackedOps { pm: self, scratch };
        forward_ops(&mut ops, tokens, ws)
    }

    /// Resumable forward on packed weights: logits for `tokens` appended
    /// at `start_pos`, attending over the K/V cached in `state` — the
    /// packed twin of [`crate::model::forward::forward_extend_ck`].
    pub fn forward_extend(
        &self,
        tokens: &[usize],
        start_pos: usize,
        ws: &mut Workspace,
        scratch: &mut KernelScratch,
        state: &mut DecodeState,
    ) -> Result<Tensor> {
        let mut ops = PackedOps { pm: self, scratch };
        forward_extend(&mut ops, tokens, start_pos, ws, state)
    }

    /// One prompt pass (reset + extend from 0), returning the prompt's
    /// last-position logits row — what the prefix cache stores.
    pub fn prompt_pass(
        &self,
        prompt: &[usize],
        ws: &mut Workspace,
        scratch: &mut KernelScratch,
        state: &mut DecodeState,
    ) -> Result<Vec<f32>> {
        let mut ops = PackedOps { pm: self, scratch };
        prompt_pass(&mut ops, prompt, ws, state)
    }

    /// Option logprobs given a state positioned at the prompt (see
    /// [`crate::model::forward::score_options`] for the semantics).
    pub fn option_logprobs(
        &self,
        prompt_len: usize,
        last_row: &[f32],
        options: &[Vec<usize>],
        ws: &mut Workspace,
        scratch: &mut KernelScratch,
        state: &mut DecodeState,
    ) -> Result<Vec<f64>> {
        let mut ops = PackedOps { pm: self, scratch };
        option_logprobs(&mut ops, prompt_len, last_row, options, ws, state)
    }

    /// Prefix-reuse MCQ scoring on the packed engine: one prompt pass +
    /// one short extension per option.
    pub fn score_options(
        &self,
        prompt: &[usize],
        options: &[Vec<usize>],
        ws: &mut Workspace,
        scratch: &mut KernelScratch,
        state: &mut DecodeState,
    ) -> Result<Vec<f64>> {
        let last = self.prompt_pass(prompt, ws, scratch, state)?;
        self.option_logprobs(prompt.len(), &last, options, ws, scratch, state)
    }

    /// Greedy generation on the packed engine: the same shared decode
    /// loop as `forward::generate_greedy`, over any state backing —
    /// pass a paged state to decode out of a shared [`KvArena`]
    /// (`crate::model::decode::KvArena`).
    pub fn generate_greedy(
        &self,
        prompt: &[usize],
        n_new: usize,
        ws: &mut Workspace,
        scratch: &mut KernelScratch,
        state: &mut DecodeState,
    ) -> Result<Vec<usize>> {
        let mut ops = PackedOps { pm: self, scratch };
        crate::model::forward::generate_greedy_ops(&mut ops, prompt, n_new, ws, state)
    }

    /// Teacher-forced continuation log-likelihood (the MCQ scoring rule)
    /// via a full `prompt+continuation` recompute — the seed oracle path
    /// mirroring `forward::continuation_logprob` on the packed engine;
    /// hot paths score through [`Self::score_options`] instead.
    pub fn continuation_logprob(
        &self,
        prompt: &[usize],
        continuation: &[usize],
        ws: &mut Workspace,
        scratch: &mut KernelScratch,
    ) -> Result<f64> {
        assert!(!continuation.is_empty());
        let mut seq = prompt.to_vec();
        seq.extend_from_slice(continuation);
        let logits = self.forward_with(&seq, ws, scratch)?;
        Ok(continuation_logprob_from_logits(&logits, prompt.len(), continuation))
    }

    /// Widest linear input dimension (incl. the embedding read by the
    /// tied LM head) — what a [`KernelScratch`] needs to hold.
    pub fn max_in_dim(&self) -> usize {
        self.linears
            .values()
            .map(|l| l.in_dim())
            .chain(std::iter::once(self.embedding.cols()))
            .max()
            .unwrap_or(0)
    }

    /// A kernel scratch pre-grown to this model's widest layer, with
    /// the byte→lane LUTs of every plane (linears + the packed
    /// embedding read by the tied LM head) pre-built — so a long-lived
    /// worker's first decode token pays neither buffer growth nor LUT
    /// construction (`scratch.lut_builds()` stays flat across
    /// forwards; asserted in `kernel_micro` and the tests below). The
    /// same f32 tables serve every blocked impl — the SIMD kernels
    /// read them for INT2 gathers and row-end tails, and rebuild only
    /// the 16-entry in-register nibble table per row — so prewarming
    /// is impl-agnostic and nothing extra is needed for `Auto`/`Simd`.
    pub fn prewarmed_scratch(&self) -> KernelScratch {
        let mut scratch = KernelScratch::with_capacity(self.max_in_dim());
        for lin in self.linears.values() {
            scratch.prewarm_linear(lin);
        }
        scratch.prewarm_matrix(&self.embedding);
        scratch
    }

    /// Weight bytes one full-sequence forward streams: packed linear
    /// planes + the packed embedding (read in full by the tied LM head)
    /// + FP norm gains. Compare against
    /// `Checkpoint::fp32_bytes` of the effective checkpoint for the
    /// packed-vs-f32 traffic ratio.
    pub fn weight_bytes_per_forward(&self) -> u64 {
        let linear: u64 = self.linears.values().map(|l| l.weight_bytes() as u64).sum();
        let emb = self.embedding.packed_bytes() as u64;
        let fp: u64 = self.fp_tensors.values().map(|t| t.len() as u64 * 4).sum();
        linear + emb + fp
    }

    pub fn n_linears(&self) -> usize {
        self.linears.len()
    }
}

/// [`ForwardOps`] over packed planes: linears and the LM head run the
/// kernel engine; embedding rows dequantize straight out of the packed
/// bytes; norm gains come from the FP passthrough set.
pub(crate) struct PackedOps<'a, 'b> {
    pm: &'a PackedModel,
    scratch: &'b mut KernelScratch,
}

impl PackedModel {
    /// Borrow this model as [`ForwardOps`] for the shared transformer
    /// loop (the generic scoring session in `eval` drives it).
    pub(crate) fn ops<'a, 'b>(&'a self, scratch: &'b mut KernelScratch) -> PackedOps<'a, 'b> {
        PackedOps { pm: self, scratch }
    }
}

impl ForwardOps for PackedOps<'_, '_> {
    fn config(&self) -> &PicoLlamaConfig {
        &self.pm.config
    }

    fn embed(&mut self, tok: usize, out: &mut [f32]) -> Result<()> {
        self.pm.embedding.dequant_row_into(tok, out);
        Ok(())
    }

    fn linear(&mut self, name: &str, y: &mut [f32], x: &[f32], seq: usize) -> Result<()> {
        let pm = self.pm;
        let lin = pm
            .linears
            .get(name)
            .ok_or_else(|| anyhow!("missing packed linear '{name}'"))?;
        kernels::gemm(y, x, seq, lin, &mut *self.scratch);
        Ok(())
    }

    fn lm_head(&mut self, y: &mut [f32], x: &[f32], seq: usize) -> Result<()> {
        let pm = self.pm;
        if pm.config.tie_embeddings {
            kernels::gemm_matrix(y, x, seq, &pm.embedding, &mut *self.scratch);
        } else {
            let lin = pm
                .linears
                .get("lm_head")
                .ok_or_else(|| anyhow!("missing packed linear 'lm_head'"))?;
            kernels::gemm(y, x, seq, lin, &mut *self.scratch);
        }
        Ok(())
    }

    fn fp(&self, name: &str) -> Result<&Tensor> {
        self.pm
            .fp_tensors
            .get(name)
            .ok_or_else(|| anyhow!("missing fp tensor '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::quantized::{quantize_model, Method};
    use crate::model::{forward, Checkpoint, PicoLlamaConfig};
    use crate::split::SplitConfig;
    use crate::util::stats::max_abs_diff;

    fn ck() -> Checkpoint {
        let mut ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 17);
        ck.amplify_outliers(0.002, 10.0, 5);
        ck
    }

    #[test]
    fn packed_forward_matches_effective_reference() {
        let ck = ck();
        let toks = [1usize, 6, 11, 3, 2];
        for method in [
            Method::Baseline,
            Method::SplitQuant(SplitConfig::default()),
            Method::Ocs { expand_ratio: 0.05 },
        ] {
            let qm = quantize_model(&ck, Bits::Int4, &method).unwrap();
            let pm = PackedModel::from_qmodel(&qm).unwrap();
            let eff = qm.effective_checkpoint();
            let mut ws = Workspace::new(&ck.config, 16);
            let want = forward::forward(&eff, &toks, &mut ws).unwrap();
            let got = pm.forward(&toks, &mut ws).unwrap();
            assert_eq!(got.shape(), want.shape());
            let diff = max_abs_diff(got.data(), want.data());
            assert!(diff < 1e-3, "{}: logit diff {diff}", qm.method_name);
        }
    }

    #[test]
    fn packed_bytes_fraction_of_f32() {
        let ck = ck();
        let qm = quantize_model(&ck, Bits::Int4, &Method::Baseline).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let f32_bytes = qm.effective_checkpoint().fp32_bytes();
        // INT4 plain: everything except the (tiny) norm gains is 1/8.
        assert!(
            (pm.weight_bytes_per_forward() as f64) < 0.2 * f32_bytes as f64,
            "packed {} vs f32 {f32_bytes}",
            pm.weight_bytes_per_forward()
        );
        assert_eq!(pm.n_linears(), qm.linears.len());
    }

    #[test]
    fn continuation_logprob_close_to_reference() {
        let ck = ck();
        let qm =
            quantize_model(&ck, Bits::Int8, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let eff = qm.effective_checkpoint();
        let mut ws = Workspace::new(&ck.config, 16);
        let mut scratch = KernelScratch::new();
        let a = forward::continuation_logprob(&eff, &[1, 5, 9], &[12, 2], &mut ws).unwrap();
        let b = pm
            .continuation_logprob(&[1, 5, 9], &[12, 2], &mut ws, &mut scratch)
            .unwrap();
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }

    #[test]
    fn packed_score_options_matches_full_recompute() {
        let ck = ck();
        let qm =
            quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let mut ws = Workspace::new(&ck.config, 16);
        let mut scratch = pm.prewarmed_scratch();
        let mut state = DecodeState::new(&ck.config);
        let prompt = [2usize, 8, 5];
        let options: Vec<Vec<usize>> = vec![vec![3], vec![11, 4], vec![6, 1, 9]];
        let fast = pm.score_options(&prompt, &options, &mut ws, &mut scratch, &mut state).unwrap();
        for (opt, lp) in options.iter().zip(&fast) {
            let want = pm.continuation_logprob(&prompt, opt, &mut ws, &mut scratch).unwrap();
            assert!((lp - want).abs() < 1e-6, "{lp} vs {want}");
        }
        assert!(pm.max_in_dim() >= pm.config.d_model);
    }

    #[test]
    fn prewarmed_scratch_never_builds_luts_on_the_hot_path() {
        let ck = ck();
        let qm =
            quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let mut ws = Workspace::new(&ck.config, 16);
        let mut scratch = pm.prewarmed_scratch();
        let built = scratch.lut_builds();
        assert!(built > 0, "prewarm builds the planes' tables");
        let mut state = DecodeState::new(&ck.config);
        pm.forward_with(&[1, 6, 11], &mut ws, &mut scratch).unwrap();
        pm.forward_extend(&[3], 0, &mut ws, &mut scratch, &mut state).unwrap();
        assert_eq!(scratch.lut_builds(), built, "forward built LUTs after prewarm");
    }

    #[test]
    fn scalar_and_lut_engines_agree_on_logits() {
        use crate::kernels::KernelImpl;
        let ck = ck();
        let toks = [1usize, 6, 11, 3, 2];
        let qm =
            quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let mut ws = Workspace::new(&ck.config, 16);
        let mut lut = pm.prewarmed_scratch();
        let mut scalar = pm.prewarmed_scratch();
        scalar.set_kernel_impl(KernelImpl::Scalar);
        let a = pm.forward_with(&toks, &mut ws, &mut lut).unwrap();
        let b = pm.forward_with(&toks, &mut ws, &mut scalar).unwrap();
        let scale = b.data().iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1.0) as f64;
        let diff = max_abs_diff(a.data(), b.data());
        assert!(diff < 1e-4 * scale, "LUT logits drifted {diff} from the scalar oracle");
    }

    #[test]
    fn packed_greedy_paged_matches_owned() {
        use crate::model::decode::KvArena;
        use std::sync::Arc;
        let ck = ck();
        let qm =
            quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default())).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let mut ws = Workspace::new(&ck.config, 16);
        let mut scratch = pm.prewarmed_scratch();
        let mut owned = DecodeState::new(&ck.config);
        let want = pm
            .generate_greedy(&[2, 7], 5, &mut ws, &mut scratch, &mut owned)
            .unwrap();
        assert_eq!(want.len(), 5);
        let arena = Arc::new(KvArena::new(&ck.config, 4, 8));
        let mut paged = DecodeState::paged(&ck.config, Arc::clone(&arena));
        let got = pm
            .generate_greedy(&[2, 7], 5, &mut ws, &mut scratch, &mut paged)
            .unwrap();
        assert_eq!(want, got, "paged greedy decode must match owned");
        drop(paged);
        assert_eq!(arena.blocks_in_use(), 0);
    }

    #[test]
    fn packed_extend_matches_full_forward() {
        let ck = ck();
        let qm = quantize_model(&ck, Bits::Int8, &Method::Baseline).unwrap();
        let pm = PackedModel::from_qmodel(&qm).unwrap();
        let toks = [1usize, 6, 11, 3, 2, 9];
        let mut ws = Workspace::new(&ck.config, 16);
        let mut scratch = KernelScratch::new();
        let full = pm.forward(&toks, &mut ws).unwrap();
        let mut state = DecodeState::new(&ck.config);
        let head = pm.forward_extend(&toks[..2], 0, &mut ws, &mut scratch, &mut state).unwrap();
        let tail = pm.forward_extend(&toks[2..], 2, &mut ws, &mut scratch, &mut state).unwrap();
        for t in 0..2 {
            assert_eq!(head.row(t), full.row(t), "head row {t}");
        }
        for t in 2..toks.len() {
            assert_eq!(tail.row(t - 2), full.row(t), "tail row {t}");
        }
    }
}
