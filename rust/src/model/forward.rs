//! CPU reference forward pass of picollama — now resumable.
//!
//! This is the runtime-independent evaluation path: it runs the exact
//! Llama-3 computation (RMSNorm → RoPE GQA attention → SwiGLU, residual
//! streams, tied LM head) in plain f32 on the CPU. It is used by
//!
//! * the Table-1 accuracy harness (scores every quantization arm without
//!   needing PJRT),
//! * the §4.1 functional-preservation check (original vs split FP model),
//! * calibration for GPTQ-lite and activation splitting,
//! * cross-validation of the PJRT/HLO path (`runtime` executes the same
//!   checkpoint; logits must agree to FP tolerance).
//!
//! The transformer loop is built around a resumable
//! [`DecodeState`](crate::model::decode::DecodeState): per-layer K/V
//! persists across calls and [`forward_extend`] computes only the
//! appended positions, attending over the cached prefix (RoPE applied
//! at absolute positions). A whole-sequence forward is simply an extend
//! from position 0, so the full-sequence path and the incremental path
//! cannot drift — they are the same loop. Both execution engines (this
//! FP reference via [`CkOps`] and the packed-integer engine via
//! [`ForwardOps`]) share it.
//!
//! Weight convention matches the JAX model: all linear weights are
//! `[out, in]` and apply as `y = x · Wᵀ`.

use crate::model::decode::DecodeState;
use crate::tensor::Tensor;

use super::{Checkpoint, PicoLlamaConfig};
use anyhow::Result;

/// Scratch buffers reused across layers/positions to keep the forward
/// allocation-light (matters when scoring 4×1165 sequences). Sized for
/// a chunk of `max_seq` new positions at construction; every buffer
/// grows on demand, so a `Workspace` can be built small and reused for
/// any request up to the model's `max_seq`.
pub struct Workspace {
    x: Vec<f32>,        // [seq, d]
    xn: Vec<f32>,       // [seq, d]
    q: Vec<f32>,        // [seq, d]
    k: Vec<f32>,        // [seq, kv_dim]
    v: Vec<f32>,        // [seq, kv_dim]
    attn_out: Vec<f32>, // [seq, d]
    scores: Vec<f32>,   // [total] — spans cached + new positions
    gate: Vec<f32>,     // [seq, d_ff]
    up: Vec<f32>,       // [seq, d_ff]
    mlp_out: Vec<f32>,  // [seq, d]
}

impl Workspace {
    pub fn new(cfg: &PicoLlamaConfig, max_seq: usize) -> Workspace {
        let d = cfg.d_model;
        Workspace {
            x: vec![0.0; max_seq * d],
            xn: vec![0.0; max_seq * d],
            q: vec![0.0; max_seq * d],
            k: vec![0.0; max_seq * cfg.kv_dim()],
            v: vec![0.0; max_seq * cfg.kv_dim()],
            attn_out: vec![0.0; max_seq * d],
            scores: vec![0.0; max_seq],
            gate: vec![0.0; max_seq * cfg.d_ff],
            up: vec![0.0; max_seq * cfg.d_ff],
            mlp_out: vec![0.0; max_seq * d],
        }
    }

    /// Grow buffers to hold a `seq`-position chunk attending over
    /// `total` positions. No-op when already large enough.
    fn ensure(&mut self, cfg: &PicoLlamaConfig, seq: usize, total: usize) {
        let grow = |b: &mut Vec<f32>, n: usize| {
            if b.len() < n {
                b.resize(n, 0.0);
            }
        };
        let d = cfg.d_model;
        grow(&mut self.x, seq * d);
        grow(&mut self.xn, seq * d);
        grow(&mut self.q, seq * d);
        grow(&mut self.k, seq * cfg.kv_dim());
        grow(&mut self.v, seq * cfg.kv_dim());
        grow(&mut self.attn_out, seq * d);
        grow(&mut self.scores, total);
        grow(&mut self.gate, seq * cfg.d_ff);
        grow(&mut self.up, seq * cfg.d_ff);
        grow(&mut self.mlp_out, seq * d);
    }
}

/// RMSNorm: x · γ / rms(x).
fn rmsnorm(out: &mut [f32], x: &[f32], gamma: &[f32], eps: f64, seq: usize, d: usize) {
    for t in 0..seq {
        let row = &x[t * d..(t + 1) * d];
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = &mut out[t * d..(t + 1) * d];
        for i in 0..d {
            orow[i] = (row[i] as f64 * inv) as f32 * gamma[i];
        }
    }
}

/// In-place rotary position embedding over `[seq, n_heads*head_dim]`
/// where row `t` sits at absolute position `start + t`, pairing
/// dimension (2i, 2i+1) within each head — matches the JAX model.
fn rope_from(x: &mut [f32], seq: usize, start: usize, n_heads: usize, head_dim: usize, theta: f64) {
    let half = head_dim / 2;
    for t in 0..seq {
        for h in 0..n_heads {
            let base = t * n_heads * head_dim + h * head_dim;
            for i in 0..half {
                let freq = 1.0 / theta.powf(2.0 * i as f64 / head_dim as f64);
                let ang = (start + t) as f64 * freq;
                let (sin, cos) = ang.sin_cos();
                let a = x[base + 2 * i] as f64;
                let b = x[base + 2 * i + 1] as f64;
                x[base + 2 * i] = (a * cos - b * sin) as f32;
                x[base + 2 * i + 1] = (a * sin + b * cos) as f32;
            }
        }
    }
}

/// RoPE from position 0 (whole-sequence form, kept for tests/tools).
#[cfg(test)]
fn rope(x: &mut [f32], seq: usize, n_heads: usize, head_dim: usize, theta: f64) {
    rope_from(x, seq, 0, n_heads, head_dim, theta);
}

/// y[seq, out] = x[seq, in] · W[out, in]ᵀ.
fn linear(y: &mut [f32], x: &[f32], w: &Tensor, seq: usize) {
    let (out_dim, in_dim) = (w.shape()[0], w.shape()[1]);
    debug_assert_eq!(x.len(), seq * in_dim);
    debug_assert_eq!(y.len(), seq * out_dim);
    // x[seq,in] · Wᵀ[in,out]: use matmul_into with B = Wᵀ... avoiding the
    // transpose copy: compute y[t,o] = Σ_i x[t,i]·W[o,i] row-by-row with
    // the blocked kernel over W directly (W rows are contiguous).
    y.iter_mut().for_each(|v| *v = 0.0);
    for t in 0..seq {
        let xr = &x[t * in_dim..(t + 1) * in_dim];
        let yr = &mut y[t * out_dim..(t + 1) * out_dim];
        for o in 0..out_dim {
            let wrow = &w.data()[o * in_dim..(o + 1) * in_dim];
            let mut acc = 0.0f32;
            let chunks = in_dim / 4 * 4;
            let mut i = 0;
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            while i < chunks {
                s0 += xr[i] * wrow[i];
                s1 += xr[i + 1] * wrow[i + 1];
                s2 += xr[i + 2] * wrow[i + 2];
                s3 += xr[i + 3] * wrow[i + 3];
                i += 4;
            }
            acc += s0 + s1 + s2 + s3;
            while i < in_dim {
                acc += xr[i] * wrow[i];
                i += 1;
            }
            yr[o] = acc;
        }
    }
}

/// Numerically-stable softmax in place.
fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f64;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v as f64;
    }
    let inv = (1.0 / sum) as f32;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// The per-model primitives the shared transformer loop is generic
/// over. Implemented by the FP reference (checkpoint tensors, below)
/// and by the packed-integer engine ([`crate::model::packed`]); the
/// RMSNorm/RoPE/attention/SwiGLU math in [`forward_extend`] is shared,
/// so both engines execute the *same* f32 activation path and differ
/// only in how linear layers and embedding rows are produced.
pub(crate) trait ForwardOps {
    fn config(&self) -> &PicoLlamaConfig;
    /// Write the embedding row of `tok` into `out` (`[d_model]`).
    fn embed(&mut self, tok: usize, out: &mut [f32]) -> Result<()>;
    /// y[seq, out] = x[seq, in] · W(name)ᵀ (overwrites `y`).
    fn linear(&mut self, name: &str, y: &mut [f32], x: &[f32], seq: usize) -> Result<()>;
    /// Final LM-head projection: y[seq, vocab] (overwrites `y`).
    fn lm_head(&mut self, y: &mut [f32], x: &[f32], seq: usize) -> Result<()>;
    /// FP32 passthrough tensor (norm gains).
    fn fp(&self, name: &str) -> Result<&Tensor>;
}

/// Full forward: token ids → logits `[seq, vocab]`.
///
/// Runs as a [`forward_extend`] from position 0 over a fresh decode
/// state — fine for the ≤64-token MCQ sequences this crate evaluates;
/// hot paths hold a [`DecodeState`] and extend instead of recomputing.
pub fn forward(ck: &Checkpoint, tokens: &[usize], ws: &mut Workspace) -> Result<Tensor> {
    forward_tapped(ck, tokens, ws, &mut |_, _, _| {})
}

/// Reference ops over an FP checkpoint, with an optional activation tap.
pub(crate) struct CkOps<'a, 'b> {
    ck: &'a Checkpoint,
    tap: Option<&'b mut dyn FnMut(&str, &[f32], usize)>,
}

impl<'a> CkOps<'a, 'static> {
    pub(crate) fn new(ck: &'a Checkpoint) -> CkOps<'a, 'static> {
        CkOps { ck, tap: None }
    }
}

impl ForwardOps for CkOps<'_, '_> {
    fn config(&self) -> &PicoLlamaConfig {
        &self.ck.config
    }

    fn embed(&mut self, tok: usize, out: &mut [f32]) -> Result<()> {
        out.copy_from_slice(self.ck.get("embed.tok")?.row(tok));
        Ok(())
    }

    fn linear(&mut self, name: &str, y: &mut [f32], x: &[f32], seq: usize) -> Result<()> {
        if let Some(tap) = self.tap.as_mut() {
            tap(name, x, seq);
        }
        linear(y, x, self.ck.get(name)?, seq);
        Ok(())
    }

    fn lm_head(&mut self, y: &mut [f32], x: &[f32], seq: usize) -> Result<()> {
        let head = if self.ck.config.tie_embeddings {
            self.ck.get("embed.tok")?
        } else {
            self.ck.get("lm_head")?
        };
        linear(y, x, head, seq);
        Ok(())
    }

    fn fp(&self, name: &str) -> Result<&Tensor> {
        self.ck.get(name)
    }
}

/// Forward with an activation tap: `tap(linear_name, input, seq)` fires
/// with the `[seq, in]` input of every linear layer. Used by GPTQ-lite
/// Hessian accumulation and activation-split calibration.
pub fn forward_tapped(
    ck: &Checkpoint,
    tokens: &[usize],
    ws: &mut Workspace,
    tap: &mut dyn FnMut(&str, &[f32], usize),
) -> Result<Tensor> {
    forward_ops(&mut CkOps { ck, tap: Some(tap) }, tokens, ws)
}

/// Whole-sequence forward over a fresh decode state (an extend from
/// position 0) — the shape every pre-DecodeState caller expects.
pub(crate) fn forward_ops<O: ForwardOps>(
    ops: &mut O,
    tokens: &[usize],
    ws: &mut Workspace,
) -> Result<Tensor> {
    let mut state = DecodeState::new(ops.config());
    forward_extend(ops, tokens, 0, ws, &mut state)
}

/// The shared resumable transformer loop: compute logits for `tokens`
/// appended at absolute position `start_pos`, attending over the K/V
/// cached in `state` for positions `0..start_pos` plus the chunk
/// itself. Returns logits `[tokens.len(), vocab]` for the *new*
/// positions only.
///
/// `start_pos` may rewind a longer state (`start_pos <= state.len()`):
/// the state is truncated first, which is how MCQ scoring rolls back to
/// the prompt between option continuations. An extend from 0 over an
/// empty state is exactly the whole-sequence forward — same loop, same
/// FP operation order — so full and incremental execution agree
/// bit-for-bit (property-tested in `rust/tests/decode_state.rs`).
pub(crate) fn forward_extend<O: ForwardOps>(
    ops: &mut O,
    tokens: &[usize],
    start_pos: usize,
    ws: &mut Workspace,
    state: &mut DecodeState,
) -> Result<Tensor> {
    forward_extend_rows(ops, tokens, start_pos, ws, state, false)
}

/// [`forward_extend`] with an optional last-row-only LM head: when
/// `last_only` is set, the final norm + vocab projection (the single
/// largest matmul) run for just the chunk's last position and the
/// returned logits are `[1, vocab]`. The transformer layers are
/// unchanged — K/V for every chunk position is still cached — and the
/// last row is bit-identical to the full projection's last row (the
/// per-row math is position-independent). This is the prompt-pass hot
/// path: scoring only ever needs the prompt's final logits.
fn forward_extend_rows<O: ForwardOps>(
    ops: &mut O,
    tokens: &[usize],
    start_pos: usize,
    ws: &mut Workspace,
    state: &mut DecodeState,
    last_only: bool,
) -> Result<Tensor> {
    let cfg = ops.config().clone();
    let seq = tokens.len();
    let total = start_pos + seq;
    assert!(seq > 0, "empty token chunk");
    assert!(total <= cfg.max_seq, "sequence {total} exceeds max_seq {}", cfg.max_seq);
    assert!(
        start_pos <= state.len(),
        "extend at position {start_pos} but only {} positions cached",
        state.len()
    );
    state.truncate(start_pos);
    state.reserve(total)?;
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    let kvd = cfg.kv_dim();
    let groups = cfg.n_heads / cfg.n_kv_heads;
    ws.ensure(&cfg, seq, total);

    // Embedding lookup for the new positions.
    for (t, &tok) in tokens.iter().enumerate() {
        assert!(tok < cfg.vocab, "token {tok} out of vocab");
        ops.embed(tok, &mut ws.x[t * d..(t + 1) * d])?;
    }

    for l in 0..cfg.n_layers {
        let pre = format!("layers.{l}");
        // --- Attention block ---
        let gamma = ops.fp(&format!("{pre}.norm_attn"))?;
        rmsnorm(&mut ws.xn, &ws.x, gamma.data(), cfg.norm_eps, seq, d);

        ops.linear(&format!("{pre}.attn.wq"), &mut ws.q[..seq * d], &ws.xn[..seq * d], seq)?;
        ops.linear(&format!("{pre}.attn.wk"), &mut ws.k[..seq * kvd], &ws.xn[..seq * d], seq)?;
        ops.linear(&format!("{pre}.attn.wv"), &mut ws.v[..seq * kvd], &ws.xn[..seq * d], seq)?;

        rope_from(&mut ws.q[..seq * d], seq, start_pos, cfg.n_heads, hd, cfg.rope_theta);
        rope_from(&mut ws.k[..seq * kvd], seq, start_pos, cfg.n_kv_heads, hd, cfg.rope_theta);

        // Commit the chunk's K/V, then attend over every cached
        // position (prefix + chunk) — causal per new position. Cached
        // rows are read through the per-position accessors, which have
        // the same within-row float layout for the owned and paged
        // backings: the FP operation order below is byte-identical for
        // both, so paged decode ≡ contiguous decode bit-for-bit.
        state.append_layer(l, start_pos, &ws.k[..seq * kvd], &ws.v[..seq * kvd]);

        let scale = 1.0 / (hd as f64).sqrt();
        for h in 0..cfg.n_heads {
            let kvh = h / groups;
            for t in 0..seq {
                let abs = start_pos + t;
                let qv = &ws.q[t * d + h * hd..t * d + (h + 1) * hd];
                for s in 0..=abs {
                    let kv = &state.k_row(l, s)[kvh * hd..(kvh + 1) * hd];
                    let dot: f32 = qv.iter().zip(kv).map(|(&a, &b)| a * b).sum();
                    ws.scores[s] = (dot as f64 * scale) as f32;
                }
                softmax(&mut ws.scores[..=abs]);
                let out = &mut ws.attn_out[t * d + h * hd..t * d + (h + 1) * hd];
                out.iter_mut().for_each(|v| *v = 0.0);
                for s in 0..=abs {
                    let w = ws.scores[s];
                    let vv = &state.v_row(l, s)[kvh * hd..(kvh + 1) * hd];
                    for i in 0..hd {
                        out[i] += w * vv[i];
                    }
                }
            }
        }

        // Output projection + residual.
        ops.linear(&format!("{pre}.attn.wo"), &mut ws.xn[..seq * d], &ws.attn_out[..seq * d], seq)?;
        for i in 0..seq * d {
            ws.x[i] += ws.xn[i];
        }

        // --- MLP block (SwiGLU) ---
        let gamma = ops.fp(&format!("{pre}.norm_mlp"))?;
        rmsnorm(&mut ws.xn, &ws.x, gamma.data(), cfg.norm_eps, seq, d);
        let dff = cfg.d_ff;
        ops.linear(&format!("{pre}.mlp.gate"), &mut ws.gate[..seq * dff], &ws.xn[..seq * d], seq)?;
        ops.linear(&format!("{pre}.mlp.up"), &mut ws.up[..seq * dff], &ws.xn[..seq * d], seq)?;
        for i in 0..seq * dff {
            let g = ws.gate[i];
            // SiLU(g) * up
            let silu = g / (1.0 + (-g).exp());
            ws.gate[i] = silu * ws.up[i];
        }
        ops.linear(
            &format!("{pre}.mlp.down"),
            &mut ws.mlp_out[..seq * d],
            &ws.gate[..seq * dff],
            seq,
        )?;
        for i in 0..seq * d {
            ws.x[i] += ws.mlp_out[i];
        }
    }
    state.commit(total);

    // Final norm + LM head — all new positions, or just the last one.
    let gamma = ops.fp("norm.final")?;
    if last_only {
        let t0 = (seq - 1) * d;
        rmsnorm(&mut ws.xn[..d], &ws.x[t0..t0 + d], gamma.data(), cfg.norm_eps, 1, d);
        let mut logits = vec![0.0f32; cfg.vocab];
        ops.lm_head(&mut logits, &ws.xn[..d], 1)?;
        return Ok(Tensor::new(&[1, cfg.vocab], logits));
    }
    rmsnorm(&mut ws.xn, &ws.x, gamma.data(), cfg.norm_eps, seq, d);
    let mut logits = vec![0.0f32; seq * cfg.vocab];
    ops.lm_head(&mut logits, &ws.xn[..seq * d], seq)?;
    Ok(Tensor::new(&[seq, cfg.vocab], logits))
}

/// Reference-engine [`forward_extend`]: logits for `tokens` appended at
/// `start_pos` over the cached prefix in `state` (the packed twin is
/// [`crate::model::packed::PackedModel::forward_extend`]).
pub fn forward_extend_ck(
    ck: &Checkpoint,
    tokens: &[usize],
    start_pos: usize,
    ws: &mut Workspace,
    state: &mut DecodeState,
) -> Result<Tensor> {
    forward_extend(&mut CkOps::new(ck), tokens, start_pos, ws, state)
}

/// One prompt pass: reset the state and extend with `prompt` from
/// position 0, returning the last position's logits row (what MCQ
/// scoring and the prompt-prefix cache need). The LM head runs for the
/// last position only — the earlier rows' vocab projections are never
/// computed.
pub(crate) fn prompt_pass<O: ForwardOps>(
    ops: &mut O,
    prompt: &[usize],
    ws: &mut Workspace,
    state: &mut DecodeState,
) -> Result<Vec<f32>> {
    state.reset();
    let logits = forward_extend_rows(ops, prompt, 0, ws, state, true)?;
    Ok(logits.row(0).to_vec())
}

/// Teacher-forced log-likelihood of every option continuation given a
/// state positioned at the prompt and the prompt's last logits row.
/// Each option costs one extension of `len−1` positions (single-token
/// options cost zero extra forwards); the state is rolled back to the
/// prompt between options.
pub(crate) fn option_logprobs<O: ForwardOps>(
    ops: &mut O,
    prompt_len: usize,
    last_row: &[f32],
    options: &[Vec<usize>],
    ws: &mut Workspace,
    state: &mut DecodeState,
) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(options.len());
    for opt in options {
        anyhow::ensure!(!opt.is_empty(), "empty option continuation");
        let mut lp = log_prob(last_row, opt[0]);
        if opt.len() > 1 {
            // Rollback to the prompt is implicit: extending at
            // `prompt_len` truncates the previous option's tail.
            let logits = forward_extend(ops, &opt[..opt.len() - 1], prompt_len, ws, state)?;
            for (i, &tok) in opt[1..].iter().enumerate() {
                lp += log_prob(logits.row(i), tok);
            }
        }
        out.push(lp);
    }
    Ok(out)
}

/// Prefix-reuse MCQ scoring on the reference engine: one prompt pass +
/// one short extension per option (vs the seed's N full `prompt+option`
/// recomputes — see [`continuation_logprob`] for that oracle path).
pub fn score_options(
    ck: &Checkpoint,
    prompt: &[usize],
    options: &[Vec<usize>],
    ws: &mut Workspace,
    state: &mut DecodeState,
) -> Result<Vec<f64>> {
    let mut ops = CkOps::new(ck);
    let last = prompt_pass(&mut ops, prompt, ws, state)?;
    option_logprobs(&mut ops, prompt.len(), &last, options, ws, state)
}

/// Log-softmax of one logits row, returning log P(token) for `tok`.
pub fn log_prob(logits_row: &[f32], tok: usize) -> f64 {
    let max = logits_row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits_row
        .iter()
        .map(|&v| ((v as f64) - max).exp())
        .sum::<f64>()
        .ln()
        + max;
    logits_row[tok] as f64 - lse
}

/// Teacher-forced continuation log-likelihood read off a full-sequence
/// logits matrix: token at position p is predicted by logits at p−1.
/// Shared by the reference and packed scoring paths.
pub fn continuation_logprob_from_logits(
    logits: &Tensor,
    prompt_len: usize,
    continuation: &[usize],
) -> f64 {
    debug_assert!(prompt_len > 0 && !continuation.is_empty());
    let mut total = 0.0;
    for (i, &tok) in continuation.iter().enumerate() {
        total += log_prob(logits.row(prompt_len + i - 1), tok);
    }
    total
}

/// Sum of log-probs of `continuation` tokens given `prompt` (teacher-
/// forced) via a full `prompt+continuation` recompute. This is the seed
/// scoring rule kept as the oracle for the prefix-reuse path
/// ([`score_options`]); the property tests pin the two within 1e-4.
pub fn continuation_logprob(
    ck: &Checkpoint,
    prompt: &[usize],
    continuation: &[usize],
    ws: &mut Workspace,
) -> Result<f64> {
    assert!(!continuation.is_empty());
    let mut seq = prompt.to_vec();
    seq.extend_from_slice(continuation);
    let logits = forward(ck, &seq, ws)?;
    Ok(continuation_logprob_from_logits(&logits, prompt.len(), continuation))
}

/// Greedy argmax over one logits row: highest logit wins, ties broken
/// toward the **lowest** index, NaN ranking as −∞ — a delegation to
/// [`crate::eval::nan_safe_argmax_f32`], the crate's single argmax
/// rule. Every greedy decoder in the crate — the sequential loops
/// below, the packed engine's, the continuous-batching server's
/// per-session step, and the speculative decoder's draft *and* verify
/// sides ([`crate::model::specdec`]) — picks tokens through this one
/// function, so their choices cannot drift on ties. That shared
/// tie-break is a correctness requirement, not a convenience: the
/// speculative bit-identity proof compares draft proposals against
/// target argmaxes token by token.
pub fn greedy_token(logits_row: &[f32]) -> usize {
    crate::eval::nan_safe_argmax_f32(logits_row)
}

/// The shared greedy decode loop: one prompt pass, then one
/// position-extend per new token, over any engine and any state
/// backing. The serving step-loop replays this exact call sequence one
/// token at a time per session, which is what makes continuous-batched
/// generation bit-identical to this sequential function.
pub(crate) fn generate_greedy_ops<O: ForwardOps>(
    ops: &mut O,
    prompt: &[usize],
    n_new: usize,
    ws: &mut Workspace,
    state: &mut DecodeState,
) -> Result<Vec<usize>> {
    let max_seq = ops.config().max_seq;
    if n_new == 0 || prompt.len() >= max_seq {
        return Ok(Vec::new());
    }
    let mut last = prompt_pass(ops, prompt, ws, state)?;
    let mut out = Vec::with_capacity(n_new);
    loop {
        let next = greedy_token(&last);
        out.push(next);
        if out.len() == n_new || prompt.len() + out.len() >= max_seq {
            return Ok(out);
        }
        let logits = forward_extend(ops, &[next], state.len(), ws, state)?;
        last = logits.row(0).to_vec();
    }
}

/// Greedy generation (used by the INT2 "random characters" probe, E11).
/// Decodes incrementally on a [`DecodeState`]: the prompt is forwarded
/// once, then each new token costs one position-extend instead of the
/// seed's full-sequence recompute (O(n·seq) vs O(n²·seq) linears).
pub fn generate_greedy(
    ck: &Checkpoint,
    prompt: &[usize],
    n_new: usize,
    ws: &mut Workspace,
) -> Result<Vec<usize>> {
    let mut state = DecodeState::new(&ck.config);
    generate_greedy_ops(&mut CkOps::new(ck), prompt, n_new, ws, &mut state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PicoLlamaConfig;

    fn test_ck() -> Checkpoint {
        Checkpoint::random_init(&PicoLlamaConfig::test(), 42)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let logits = forward(&ck, &[1, 2, 3, 4, 5], &mut ws).unwrap();
        assert_eq!(logits.shape(), &[5, ck.config.vocab]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_is_deterministic() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let a = forward(&ck, &[7, 8, 9], &mut ws).unwrap();
        let b = forward(&ck, &[7, 8, 9], &mut ws).unwrap();
        assert_eq!(a, b, "workspace reuse must not change results");
    }

    #[test]
    fn causality_prefix_invariance() {
        // Logits at position t must not depend on tokens after t.
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let full = forward(&ck, &[3, 1, 4, 1, 5], &mut ws).unwrap();
        let prefix = forward(&ck, &[3, 1, 4], &mut ws).unwrap();
        for t in 0..3 {
            for v in 0..ck.config.vocab {
                let d = (full.at2(t, v) - prefix.at2(t, v)).abs();
                assert!(d < 1e-4, "pos {t} vocab {v}: {d}");
            }
        }
    }

    #[test]
    fn extend_matches_full_forward_exactly() {
        // Chunked extension through a decode state is the same loop as
        // the whole-sequence forward — logits must agree bit-for-bit.
        let ck = test_ck();
        let toks = [3usize, 1, 4, 1, 5, 9, 2, 6];
        let mut ws = Workspace::new(&ck.config, 16);
        let full = forward(&ck, &toks, &mut ws).unwrap();
        for split in [1usize, 3, 7] {
            let mut state = DecodeState::new(&ck.config);
            let head = forward_extend_ck(&ck, &toks[..split], 0, &mut ws, &mut state).unwrap();
            let tail = forward_extend_ck(&ck, &toks[split..], split, &mut ws, &mut state).unwrap();
            assert_eq!(state.len(), toks.len());
            for t in 0..split {
                assert_eq!(head.row(t), full.row(t), "split {split} head row {t}");
            }
            for t in split..toks.len() {
                assert_eq!(tail.row(t - split), full.row(t), "split {split} tail row {t}");
            }
        }
    }

    #[test]
    fn extend_rollback_replays_identically() {
        // Truncating the state back to the prompt and extending with a
        // different continuation matches a fresh computation.
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let mut state = DecodeState::new(&ck.config);
        forward_extend_ck(&ck, &[5, 9, 3], 0, &mut ws, &mut state).unwrap();
        let a1 = forward_extend_ck(&ck, &[7, 2], 3, &mut ws, &mut state).unwrap();
        // Roll back (implicit truncate) and replay a different branch.
        let b = forward_extend_ck(&ck, &[8], 3, &mut ws, &mut state).unwrap();
        let a2 = forward_extend_ck(&ck, &[7, 2], 3, &mut ws, &mut state).unwrap();
        assert_eq!(a1, a2, "rollback must be lossless");
        let fresh = forward(&ck, &[5, 9, 3, 8], &mut ws).unwrap();
        assert_eq!(b.row(0), fresh.row(3), "branch after rollback");
    }

    #[test]
    fn prompt_pass_matches_full_forward_last_row() {
        // The last-row-only LM head must reproduce the full
        // projection's last row exactly.
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let toks = [1usize, 5, 9, 2, 7];
        let full = forward(&ck, &toks, &mut ws).unwrap();
        let mut state = DecodeState::new(&ck.config);
        let last = prompt_pass(&mut CkOps::new(&ck), &toks, &mut ws, &mut state).unwrap();
        assert_eq!(&last[..], full.row(toks.len() - 1));
        assert_eq!(state.len(), toks.len(), "prompt pass caches every position");
    }

    #[test]
    fn score_options_matches_full_recompute() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let mut state = DecodeState::new(&ck.config);
        let prompt = [1usize, 2, 3];
        let options: Vec<Vec<usize>> = vec![vec![4, 5], vec![6], vec![7, 8, 9]];
        let fast = score_options(&ck, &prompt, &options, &mut ws, &mut state).unwrap();
        for (opt, lp) in options.iter().zip(&fast) {
            let want = continuation_logprob(&ck, &prompt, opt, &mut ws).unwrap();
            assert!((lp - want).abs() < 1e-6, "{lp} vs {want}");
        }
    }

    #[test]
    fn rope_rotation_properties() {
        // t=0 is the identity; t>0 rotates; norms are preserved.
        let head_dim = 8;
        let orig: Vec<f32> = (0..head_dim).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut x0 = orig.clone();
        rope(&mut x0, 1, 1, head_dim, 10_000.0);
        assert_eq!(x0, orig, "position 0 must be identity");

        let mut x = [orig.clone(), orig.clone()].concat();
        rope(&mut x, 2, 1, head_dim, 10_000.0);
        let rotated = &x[head_dim..];
        assert!(
            crate::util::stats::max_abs_diff(rotated, &orig) > 1e-3,
            "position 1 must rotate"
        );
        let norm = |v: &[f32]| v.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm(rotated) - norm(&orig)).abs() < 1e-5, "rotation preserves norm");

        // rope_from at an offset equals the tail of a longer rope pass.
        let mut tail = orig.clone();
        rope_from(&mut tail, 1, 1, 1, head_dim, 10_000.0);
        assert_eq!(tail, x[head_dim..], "offset rope matches in-sequence rope");
    }

    #[test]
    fn relative_position_sensitivity() {
        // Swapping two distinct prompt tokens changes the final logits
        // (positional information flows through attention).
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let a = forward(&ck, &[5, 9, 3], &mut ws).unwrap();
        let b = forward(&ck, &[9, 5, 3], &mut ws).unwrap();
        let d = crate::util::stats::max_abs_diff(a.row(2), b.row(2));
        assert!(d > 1e-6, "token order ignored");
    }

    #[test]
    fn log_prob_normalizes() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let logits = forward(&ck, &[1, 2], &mut ws).unwrap();
        let total: f64 = (0..ck.config.vocab)
            .map(|v| log_prob(logits.row(1), v).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-6, "probs sum to {total}");
    }

    #[test]
    fn continuation_logprob_is_negative_and_additive() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let lp = continuation_logprob(&ck, &[1, 2, 3], &[4, 5], &mut ws).unwrap();
        assert!(lp < 0.0);
        // One-token continuations compose.
        let lp1 = continuation_logprob(&ck, &[1, 2, 3], &[4], &mut ws).unwrap();
        let lp2 = continuation_logprob(&ck, &[1, 2, 3, 4], &[5], &mut ws).unwrap();
        assert!((lp - (lp1 + lp2)).abs() < 1e-6);
    }

    #[test]
    fn generate_respects_length() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 32);
        let out = generate_greedy(&ck, &[1, 2], 6, &mut ws).unwrap();
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|&t| t < ck.config.vocab));
    }

    #[test]
    fn generate_incremental_matches_full_recompute() {
        // The decode-state path must pick the same greedy tokens as the
        // seed's recompute-everything loop.
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 32);
        let fast = generate_greedy(&ck, &[1, 2], 5, &mut ws).unwrap();
        let mut seq = vec![1usize, 2];
        for _ in 0..5 {
            let logits = forward(&ck, &seq, &mut ws).unwrap();
            let next = logits
                .row(seq.len() - 1)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            seq.push(next);
        }
        assert_eq!(fast, seq[2..], "incremental decode must match");
    }

    #[test]
    fn generate_stops_at_max_seq() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, ck.config.max_seq);
        let prompt = vec![1usize; ck.config.max_seq - 2];
        let out = generate_greedy(&ck, &prompt, 10, &mut ws).unwrap();
        assert_eq!(out.len(), 2, "generation is clipped at max_seq");
        let none = generate_greedy(&ck, &vec![1; ck.config.max_seq], 4, &mut ws).unwrap();
        assert!(none.is_empty());
    }

    #[test]
    fn greedy_token_breaks_ties_toward_lowest_index() {
        // The crate-wide tie-break: exact ties pick the LOWEST maximal
        // index (see `eval::nan_safe_argmax`). The speculative decoder
        // compares draft and target argmaxes token by token, so every
        // greedy site must resolve ties identically.
        assert_eq!(greedy_token(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(greedy_token(&[5.0]), 0);
        assert_eq!(greedy_token(&[-1.0, -3.0, -1.0]), 0);
        // On distinct values it agrees with `Iterator::max_by`.
        let row = [0.3f32, 9.1, -2.0, 7.6, 4.4];
        let via_max_by = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(greedy_token(&row), via_max_by);
        // NaN never wins; an all-NaN row defaults to index 0.
        assert_eq!(greedy_token(&[f32::NAN, 1.0, f32::NAN]), 1);
        assert_eq!(greedy_token(&[f32::NAN, f32::NAN]), 0);
        // And it is exactly the eval-side rule.
        assert_eq!(
            greedy_token(&[2.0, 8.0, 8.0]),
            crate::eval::nan_safe_argmax_f32(&[2.0, 8.0, 8.0])
        );
    }

    #[test]
    fn paged_state_forward_matches_owned_bitwise() {
        // The same chunked extension through an arena-backed state must
        // produce byte-identical logits and greedy continuations.
        use crate::model::decode::KvArena;
        use std::sync::Arc;
        let ck = test_ck();
        let toks = [3usize, 1, 4, 1, 5, 9, 2, 6];
        let mut ws = Workspace::new(&ck.config, 16);
        let arena = Arc::new(KvArena::new(&ck.config, 3, 32));
        for split in [1usize, 4, 7] {
            let mut owned = DecodeState::new(&ck.config);
            let mut paged = DecodeState::paged(&ck.config, Arc::clone(&arena));
            let ho = forward_extend_ck(&ck, &toks[..split], 0, &mut ws, &mut owned).unwrap();
            let hp = forward_extend_ck(&ck, &toks[..split], 0, &mut ws, &mut paged).unwrap();
            assert_eq!(ho, hp, "split {split} head");
            let to = forward_extend_ck(&ck, &toks[split..], split, &mut ws, &mut owned).unwrap();
            let tp = forward_extend_ck(&ck, &toks[split..], split, &mut ws, &mut paged).unwrap();
            assert_eq!(to, tp, "split {split} tail");
            assert!(paged.blocks_held() > 0, "paged state rented blocks");
        }
        assert_eq!(arena.blocks_in_use(), 0, "dropped states returned their blocks");

        // Greedy decode over a paged state picks identical tokens.
        let want = generate_greedy(&ck, &[1, 2], 5, &mut ws).unwrap();
        let mut paged = DecodeState::paged(&ck.config, Arc::clone(&arena));
        let got =
            generate_greedy_ops(&mut CkOps::new(&ck), &[1, 2], 5, &mut ws, &mut paged).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn gqa_differs_from_zeroed_kv_heads() {
        // Sanity that the GQA head mapping is actually used: zeroing wk
        // changes the output.
        let mut ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let base = forward(&ck, &[1, 2, 3], &mut ws).unwrap();
        let name = "layers.0.attn.wk";
        ck.tensors.insert(name.into(), Tensor::zeros(&[ck.config.kv_dim(), ck.config.d_model]));
        let changed = forward(&ck, &[1, 2, 3], &mut ws).unwrap();
        assert!(crate::util::stats::max_abs_diff(base.data(), changed.data()) > 1e-6);
    }

    #[test]
    fn small_workspace_grows_on_demand() {
        // A workspace built for 2 positions transparently serves an
        // 8-token sequence (buffers grow inside forward_extend).
        let ck = test_ck();
        let mut small = Workspace::new(&ck.config, 2);
        let mut big = Workspace::new(&ck.config, 16);
        let toks = [1usize, 2, 3, 4, 5, 6, 7, 8];
        let a = forward(&ck, &toks, &mut small).unwrap();
        let b = forward(&ck, &toks, &mut big).unwrap();
        assert_eq!(a, b);
    }
}
