//! CPU reference forward pass of picollama.
//!
//! This is the runtime-independent evaluation path: it runs the exact
//! Llama-3 computation (RMSNorm → RoPE GQA attention → SwiGLU, residual
//! streams, tied LM head) in plain f32 on the CPU. It is used by
//!
//! * the Table-1 accuracy harness (scores every quantization arm without
//!   needing PJRT),
//! * the §4.1 functional-preservation check (original vs split FP model),
//! * calibration for GPTQ-lite and activation splitting,
//! * cross-validation of the PJRT/HLO path (`runtime` executes the same
//!   checkpoint; logits must agree to FP tolerance).
//!
//! Weight convention matches the JAX model: all linear weights are
//! `[out, in]` and apply as `y = x · Wᵀ`.

use crate::tensor::Tensor;

use super::{Checkpoint, PicoLlamaConfig};
use anyhow::Result;

/// Scratch buffers reused across layers/positions to keep the forward
/// allocation-light (matters when scoring 4×1165 sequences).
pub struct Workspace {
    x: Vec<f32>,        // [seq, d]
    xn: Vec<f32>,       // [seq, d]
    q: Vec<f32>,        // [seq, d]
    k: Vec<f32>,        // [seq, kv_dim]
    v: Vec<f32>,        // [seq, kv_dim]
    attn_out: Vec<f32>, // [seq, d]
    scores: Vec<f32>,   // [seq]
    gate: Vec<f32>,     // [seq, d_ff]
    up: Vec<f32>,       // [seq, d_ff]
    mlp_out: Vec<f32>,  // [seq, d]
}

impl Workspace {
    pub fn new(cfg: &PicoLlamaConfig, max_seq: usize) -> Workspace {
        let d = cfg.d_model;
        Workspace {
            x: vec![0.0; max_seq * d],
            xn: vec![0.0; max_seq * d],
            q: vec![0.0; max_seq * d],
            k: vec![0.0; max_seq * cfg.kv_dim()],
            v: vec![0.0; max_seq * cfg.kv_dim()],
            attn_out: vec![0.0; max_seq * d],
            scores: vec![0.0; max_seq],
            gate: vec![0.0; max_seq * cfg.d_ff],
            up: vec![0.0; max_seq * cfg.d_ff],
            mlp_out: vec![0.0; max_seq * d],
        }
    }
}

/// RMSNorm: x · γ / rms(x).
fn rmsnorm(out: &mut [f32], x: &[f32], gamma: &[f32], eps: f64, seq: usize, d: usize) {
    for t in 0..seq {
        let row = &x[t * d..(t + 1) * d];
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = &mut out[t * d..(t + 1) * d];
        for i in 0..d {
            orow[i] = (row[i] as f64 * inv) as f32 * gamma[i];
        }
    }
}

/// In-place rotary position embedding over `[seq, n_heads*head_dim]`,
/// pairing dimension (2i, 2i+1) within each head — matches the JAX model.
fn rope(x: &mut [f32], seq: usize, n_heads: usize, head_dim: usize, theta: f64) {
    let half = head_dim / 2;
    for t in 0..seq {
        for h in 0..n_heads {
            let base = t * n_heads * head_dim + h * head_dim;
            for i in 0..half {
                let freq = 1.0 / theta.powf(2.0 * i as f64 / head_dim as f64);
                let ang = t as f64 * freq;
                let (sin, cos) = ang.sin_cos();
                let a = x[base + 2 * i] as f64;
                let b = x[base + 2 * i + 1] as f64;
                x[base + 2 * i] = (a * cos - b * sin) as f32;
                x[base + 2 * i + 1] = (a * sin + b * cos) as f32;
            }
        }
    }
}

/// y[seq, out] = x[seq, in] · W[out, in]ᵀ.
fn linear(y: &mut [f32], x: &[f32], w: &Tensor, seq: usize) {
    let (out_dim, in_dim) = (w.shape()[0], w.shape()[1]);
    debug_assert_eq!(x.len(), seq * in_dim);
    debug_assert_eq!(y.len(), seq * out_dim);
    // x[seq,in] · Wᵀ[in,out]: use matmul_into with B = Wᵀ... avoiding the
    // transpose copy: compute y[t,o] = Σ_i x[t,i]·W[o,i] row-by-row with
    // the blocked kernel over W directly (W rows are contiguous).
    y.iter_mut().for_each(|v| *v = 0.0);
    for t in 0..seq {
        let xr = &x[t * in_dim..(t + 1) * in_dim];
        let yr = &mut y[t * out_dim..(t + 1) * out_dim];
        for o in 0..out_dim {
            let wrow = &w.data()[o * in_dim..(o + 1) * in_dim];
            let mut acc = 0.0f32;
            let chunks = in_dim / 4 * 4;
            let mut i = 0;
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            while i < chunks {
                s0 += xr[i] * wrow[i];
                s1 += xr[i + 1] * wrow[i + 1];
                s2 += xr[i + 2] * wrow[i + 2];
                s3 += xr[i + 3] * wrow[i + 3];
                i += 4;
            }
            acc += s0 + s1 + s2 + s3;
            while i < in_dim {
                acc += xr[i] * wrow[i];
                i += 1;
            }
            yr[o] = acc;
        }
    }
}

/// Numerically-stable softmax in place.
fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f64;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v as f64;
    }
    let inv = (1.0 / sum) as f32;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// The per-model primitives the shared transformer loop is generic
/// over. Implemented by the FP reference (checkpoint tensors, below)
/// and by the packed-integer engine ([`crate::model::packed`]); the
/// RMSNorm/RoPE/attention/SwiGLU math in [`forward_ops`] is shared, so
/// both engines execute the *same* f32 activation path and differ only
/// in how linear layers and embedding rows are produced.
pub(crate) trait ForwardOps {
    fn config(&self) -> &PicoLlamaConfig;
    /// Write the embedding row of `tok` into `out` (`[d_model]`).
    fn embed(&mut self, tok: usize, out: &mut [f32]) -> Result<()>;
    /// y[seq, out] = x[seq, in] · W(name)ᵀ (overwrites `y`).
    fn linear(&mut self, name: &str, y: &mut [f32], x: &[f32], seq: usize) -> Result<()>;
    /// Final LM-head projection: y[seq, vocab] (overwrites `y`).
    fn lm_head(&mut self, y: &mut [f32], x: &[f32], seq: usize) -> Result<()>;
    /// FP32 passthrough tensor (norm gains).
    fn fp(&self, name: &str) -> Result<&Tensor>;
}

/// Full forward: token ids → logits `[seq, vocab]`.
///
/// O(seq²·d) attention without KV caching — fine for the ≤64-token MCQ
/// sequences this crate evaluates.
pub fn forward(ck: &Checkpoint, tokens: &[usize], ws: &mut Workspace) -> Result<Tensor> {
    forward_tapped(ck, tokens, ws, &mut |_, _, _| {})
}

/// Reference ops over an FP checkpoint, with the activation tap.
struct CkOps<'a, 'b> {
    ck: &'a Checkpoint,
    tap: &'b mut dyn FnMut(&str, &[f32], usize),
}

impl ForwardOps for CkOps<'_, '_> {
    fn config(&self) -> &PicoLlamaConfig {
        &self.ck.config
    }

    fn embed(&mut self, tok: usize, out: &mut [f32]) -> Result<()> {
        out.copy_from_slice(self.ck.get("embed.tok")?.row(tok));
        Ok(())
    }

    fn linear(&mut self, name: &str, y: &mut [f32], x: &[f32], seq: usize) -> Result<()> {
        (self.tap)(name, x, seq);
        linear(y, x, self.ck.get(name)?, seq);
        Ok(())
    }

    fn lm_head(&mut self, y: &mut [f32], x: &[f32], seq: usize) -> Result<()> {
        let head = if self.ck.config.tie_embeddings {
            self.ck.get("embed.tok")?
        } else {
            self.ck.get("lm_head")?
        };
        linear(y, x, head, seq);
        Ok(())
    }

    fn fp(&self, name: &str) -> Result<&Tensor> {
        self.ck.get(name)
    }
}

/// Forward with an activation tap: `tap(linear_name, input, seq)` fires
/// with the `[seq, in]` input of every linear layer. Used by GPTQ-lite
/// Hessian accumulation and activation-split calibration.
pub fn forward_tapped(
    ck: &Checkpoint,
    tokens: &[usize],
    ws: &mut Workspace,
    tap: &mut dyn FnMut(&str, &[f32], usize),
) -> Result<Tensor> {
    forward_ops(&mut CkOps { ck, tap }, tokens, ws)
}

/// The shared transformer loop: embedding → n_layers × (RMSNorm → RoPE
/// GQA attention → SwiGLU, residual streams) → final norm → LM head,
/// generic over how weights execute ([`ForwardOps`]).
pub(crate) fn forward_ops<O: ForwardOps>(
    ops: &mut O,
    tokens: &[usize],
    ws: &mut Workspace,
) -> Result<Tensor> {
    let cfg = ops.config().clone();
    let seq = tokens.len();
    assert!(seq > 0 && seq <= cfg.max_seq, "seq {seq} out of range");
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    let kvd = cfg.kv_dim();
    let groups = cfg.n_heads / cfg.n_kv_heads;

    // Embedding lookup.
    for (t, &tok) in tokens.iter().enumerate() {
        assert!(tok < cfg.vocab, "token {tok} out of vocab");
        ops.embed(tok, &mut ws.x[t * d..(t + 1) * d])?;
    }

    for l in 0..cfg.n_layers {
        let pre = format!("layers.{l}");
        // --- Attention block ---
        let gamma = ops.fp(&format!("{pre}.norm_attn"))?;
        rmsnorm(&mut ws.xn, &ws.x, gamma.data(), cfg.norm_eps, seq, d);

        ops.linear(&format!("{pre}.attn.wq"), &mut ws.q[..seq * d], &ws.xn[..seq * d], seq)?;
        ops.linear(&format!("{pre}.attn.wk"), &mut ws.k[..seq * kvd], &ws.xn[..seq * d], seq)?;
        ops.linear(&format!("{pre}.attn.wv"), &mut ws.v[..seq * kvd], &ws.xn[..seq * d], seq)?;

        rope(&mut ws.q[..seq * d], seq, cfg.n_heads, hd, cfg.rope_theta);
        rope(&mut ws.k[..seq * kvd], seq, cfg.n_kv_heads, hd, cfg.rope_theta);

        // Causal attention per head.
        let scale = 1.0 / (hd as f64).sqrt();
        for h in 0..cfg.n_heads {
            let kvh = h / groups;
            for t in 0..seq {
                let qv = &ws.q[t * d + h * hd..t * d + (h + 1) * hd];
                for s in 0..=t {
                    let kv = &ws.k[s * kvd + kvh * hd..s * kvd + (kvh + 1) * hd];
                    let dot: f32 = qv.iter().zip(kv).map(|(&a, &b)| a * b).sum();
                    ws.scores[s] = (dot as f64 * scale) as f32;
                }
                softmax(&mut ws.scores[..=t]);
                let out = &mut ws.attn_out[t * d + h * hd..t * d + (h + 1) * hd];
                out.iter_mut().for_each(|v| *v = 0.0);
                for s in 0..=t {
                    let w = ws.scores[s];
                    let vv = &ws.v[s * kvd + kvh * hd..s * kvd + (kvh + 1) * hd];
                    for i in 0..hd {
                        out[i] += w * vv[i];
                    }
                }
            }
        }

        // Output projection + residual.
        ops.linear(&format!("{pre}.attn.wo"), &mut ws.xn[..seq * d], &ws.attn_out[..seq * d], seq)?;
        for i in 0..seq * d {
            ws.x[i] += ws.xn[i];
        }

        // --- MLP block (SwiGLU) ---
        let gamma = ops.fp(&format!("{pre}.norm_mlp"))?;
        rmsnorm(&mut ws.xn, &ws.x, gamma.data(), cfg.norm_eps, seq, d);
        let dff = cfg.d_ff;
        ops.linear(&format!("{pre}.mlp.gate"), &mut ws.gate[..seq * dff], &ws.xn[..seq * d], seq)?;
        ops.linear(&format!("{pre}.mlp.up"), &mut ws.up[..seq * dff], &ws.xn[..seq * d], seq)?;
        for i in 0..seq * dff {
            let g = ws.gate[i];
            // SiLU(g) * up
            let silu = g / (1.0 + (-g).exp());
            ws.gate[i] = silu * ws.up[i];
        }
        ops.linear(
            &format!("{pre}.mlp.down"),
            &mut ws.mlp_out[..seq * d],
            &ws.gate[..seq * dff],
            seq,
        )?;
        for i in 0..seq * d {
            ws.x[i] += ws.mlp_out[i];
        }
    }

    // Final norm + LM head.
    let gamma = ops.fp("norm.final")?;
    rmsnorm(&mut ws.xn, &ws.x, gamma.data(), cfg.norm_eps, seq, d);
    let mut logits = vec![0.0f32; seq * cfg.vocab];
    ops.lm_head(&mut logits, &ws.xn[..seq * d], seq)?;
    Ok(Tensor::new(&[seq, cfg.vocab], logits))
}

/// Log-softmax of one logits row, returning log P(token) for `tok`.
pub fn log_prob(logits_row: &[f32], tok: usize) -> f64 {
    let max = logits_row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits_row
        .iter()
        .map(|&v| ((v as f64) - max).exp())
        .sum::<f64>()
        .ln()
        + max;
    logits_row[tok] as f64 - lse
}

/// Teacher-forced continuation log-likelihood read off a full-sequence
/// logits matrix: token at position p is predicted by logits at p−1.
/// Shared by the reference and packed scoring paths.
pub fn continuation_logprob_from_logits(
    logits: &Tensor,
    prompt_len: usize,
    continuation: &[usize],
) -> f64 {
    debug_assert!(prompt_len > 0 && !continuation.is_empty());
    let mut total = 0.0;
    for (i, &tok) in continuation.iter().enumerate() {
        total += log_prob(logits.row(prompt_len + i - 1), tok);
    }
    total
}

/// Sum of log-probs of `continuation` tokens given `prompt` (teacher-
/// forced). The MCQ scoring rule (same as Meta's eval harness: pick the
/// option with the highest likelihood).
pub fn continuation_logprob(
    ck: &Checkpoint,
    prompt: &[usize],
    continuation: &[usize],
    ws: &mut Workspace,
) -> Result<f64> {
    assert!(!continuation.is_empty());
    let mut seq = prompt.to_vec();
    seq.extend_from_slice(continuation);
    let logits = forward(ck, &seq, ws)?;
    Ok(continuation_logprob_from_logits(&logits, prompt.len(), continuation))
}

/// Greedy generation (used by the INT2 "random characters" probe, E11).
pub fn generate_greedy(
    ck: &Checkpoint,
    prompt: &[usize],
    n_new: usize,
    ws: &mut Workspace,
) -> Result<Vec<usize>> {
    let mut seq = prompt.to_vec();
    for _ in 0..n_new {
        if seq.len() >= ck.config.max_seq {
            break;
        }
        let logits = forward(ck, &seq, ws)?;
        let last = logits.row(seq.len() - 1);
        let next = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        seq.push(next);
    }
    Ok(seq[prompt.len()..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PicoLlamaConfig;

    fn test_ck() -> Checkpoint {
        Checkpoint::random_init(&PicoLlamaConfig::test(), 42)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let logits = forward(&ck, &[1, 2, 3, 4, 5], &mut ws).unwrap();
        assert_eq!(logits.shape(), &[5, ck.config.vocab]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_is_deterministic() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let a = forward(&ck, &[7, 8, 9], &mut ws).unwrap();
        let b = forward(&ck, &[7, 8, 9], &mut ws).unwrap();
        assert_eq!(a, b, "workspace reuse must not change results");
    }

    #[test]
    fn causality_prefix_invariance() {
        // Logits at position t must not depend on tokens after t.
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let full = forward(&ck, &[3, 1, 4, 1, 5], &mut ws).unwrap();
        let prefix = forward(&ck, &[3, 1, 4], &mut ws).unwrap();
        for t in 0..3 {
            for v in 0..ck.config.vocab {
                let d = (full.at2(t, v) - prefix.at2(t, v)).abs();
                assert!(d < 1e-4, "pos {t} vocab {v}: {d}");
            }
        }
    }

    #[test]
    fn rope_rotation_properties() {
        // t=0 is the identity; t>0 rotates; norms are preserved.
        let head_dim = 8;
        let orig: Vec<f32> = (0..head_dim).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut x0 = orig.clone();
        rope(&mut x0, 1, 1, head_dim, 10_000.0);
        assert_eq!(x0, orig, "position 0 must be identity");

        let mut x = [orig.clone(), orig.clone()].concat();
        rope(&mut x, 2, 1, head_dim, 10_000.0);
        let rotated = &x[head_dim..];
        assert!(
            crate::util::stats::max_abs_diff(rotated, &orig) > 1e-3,
            "position 1 must rotate"
        );
        let norm = |v: &[f32]| v.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm(rotated) - norm(&orig)).abs() < 1e-5, "rotation preserves norm");
    }

    #[test]
    fn relative_position_sensitivity() {
        // Swapping two distinct prompt tokens changes the final logits
        // (positional information flows through attention).
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let a = forward(&ck, &[5, 9, 3], &mut ws).unwrap();
        let b = forward(&ck, &[9, 5, 3], &mut ws).unwrap();
        let d = crate::util::stats::max_abs_diff(a.row(2), b.row(2));
        assert!(d > 1e-6, "token order ignored");
    }

    #[test]
    fn log_prob_normalizes() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let logits = forward(&ck, &[1, 2], &mut ws).unwrap();
        let total: f64 = (0..ck.config.vocab)
            .map(|v| log_prob(logits.row(1), v).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-6, "probs sum to {total}");
    }

    #[test]
    fn continuation_logprob_is_negative_and_additive() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let lp = continuation_logprob(&ck, &[1, 2, 3], &[4, 5], &mut ws).unwrap();
        assert!(lp < 0.0);
        // One-token continuations compose.
        let lp1 = continuation_logprob(&ck, &[1, 2, 3], &[4], &mut ws).unwrap();
        let lp2 = continuation_logprob(&ck, &[1, 2, 3, 4], &[5], &mut ws).unwrap();
        assert!((lp - (lp1 + lp2)).abs() < 1e-6);
    }

    #[test]
    fn generate_respects_length() {
        let ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 32);
        let out = generate_greedy(&ck, &[1, 2], 6, &mut ws).unwrap();
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|&t| t < ck.config.vocab));
    }

    #[test]
    fn gqa_differs_from_zeroed_kv_heads() {
        // Sanity that the GQA head mapping is actually used: zeroing wk
        // changes the output.
        let mut ck = test_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let base = forward(&ck, &[1, 2, 3], &mut ws).unwrap();
        let name = "layers.0.attn.wk";
        ck.tensors.insert(name.into(), Tensor::zeros(&[ck.config.kv_dim(), ck.config.d_model]));
        let changed = forward(&ck, &[1, 2, 3], &mut ws).unwrap();
        assert!(crate::util::stats::max_abs_diff(base.data(), changed.data()) > 1e-6);
    }
}
