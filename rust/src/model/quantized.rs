//! Quantized model container: what the pipeline produces and what gets
//! packed into the deployable artifact.
//!
//! Per the paper's §3 rules:
//! * **Linear layers** — split (SplitQuantV2 arm) or not (baseline arm),
//!   then linearly quantized per-tensor.
//! * **Embedding** — quantized (per-row granularity, standard practice
//!   for lookup tables) but never split.
//! * **Norm gains** — kept in FP32 (negligible size, high sensitivity).

use std::collections::BTreeMap;

use crate::quant::{self, Bits, QuantizedTensor};
use crate::split::{self, QuantizedSplitLayer, SplitConfig};
use crate::tensor::Tensor;

use super::{param_inventory, Checkpoint, ParamKind};
use anyhow::{anyhow, Result};

/// How linear layers were processed.
#[derive(Clone, Debug)]
pub enum Method {
    /// Plain linear quantization (the paper's baseline arm).
    Baseline,
    /// SplitQuantV2 preprocessing then linear quantization.
    SplitQuant(SplitConfig),
    /// Outlier channel splitting baseline (§2.3 comparison).
    Ocs { expand_ratio: f64 },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Baseline => "baseline".into(),
            Method::SplitQuant(cfg) => match cfg.dynamic_k {
                Some(d) => format!("splitquantv2(k=dyn≤{})", d.k_max),
                None => format!("splitquantv2(k={})", cfg.k),
            },
            Method::Ocs { expand_ratio } => format!("ocs(ε={expand_ratio})"),
        }
    }
}

/// One quantized linear parameter.
#[derive(Clone, Debug)]
pub enum QuantParam {
    Plain(QuantizedTensor),
    Split(QuantizedSplitLayer),
    /// OCS keeps the folded effective weight (the expansion is virtual;
    /// see `split::ocs`) plus the packed size of the expanded plane.
    OcsEffective { effective: Tensor, packed_len: usize },
}

impl QuantParam {
    pub fn effective(&self) -> Tensor {
        match self {
            QuantParam::Plain(q) => q.dequantize(),
            QuantParam::Split(s) => s.effective_weight(),
            QuantParam::OcsEffective { effective, .. } => effective.clone(),
        }
    }

    pub fn packed_len(&self) -> usize {
        match self {
            QuantParam::Plain(q) => q.packed_len(),
            QuantParam::Split(s) => s.packed_len(),
            QuantParam::OcsEffective { packed_len, .. } => *packed_len,
        }
    }

    /// Number of planes (1 for plain, k for split).
    pub fn n_planes(&self) -> usize {
        match self {
            QuantParam::Plain(_) => 1,
            QuantParam::Split(s) => s.k(),
            QuantParam::OcsEffective { .. } => 1,
        }
    }
}

/// The quantized model.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub config: super::PicoLlamaConfig,
    pub bits: Bits,
    pub method_name: String,
    /// Quantized linear layers by name.
    pub linears: BTreeMap<String, QuantParam>,
    /// Quantized embedding (per-row).
    pub embedding: QuantizedTensor,
    /// FP32 passthrough tensors (norm gains).
    pub fp_tensors: BTreeMap<String, Tensor>,
}

/// Quantize one linear parameter under a method — the single source of
/// truth for the per-layer hot path, shared by the sequential reference
/// ([`quantize_model`]) and the pipeline engine ([`crate::pipeline`]).
pub fn quantize_linear_param(t: &Tensor, bits: Bits, method: &Method) -> QuantParam {
    match method {
        Method::Baseline => QuantParam::Plain(quant::quantize_per_tensor(t, bits)),
        Method::SplitQuant(cfg) => QuantParam::Split(split::split_quantize(t, cfg, bits)),
        Method::Ocs { expand_ratio } => {
            let exp = split::ocs::ocs_expand(t, *expand_ratio);
            let q = quant::quantize_per_tensor(&exp.expanded, bits);
            let effective = exp.fold(&q.dequantize());
            QuantParam::OcsEffective {
                effective,
                packed_len: q.packed_len(),
            }
        }
    }
}

/// Quantize a checkpoint with a method at a bit width. This *is* the
/// SplitQuantV2 pipeline when `method = SplitQuant` (preprocess + linear
/// quantization, §3) and the baseline when `method = Baseline`.
///
/// This is the **sequential reference implementation**: a plain loop over
/// the inventory. The production paths (`splitquant quantize --threads`,
/// the coordinator's arms) go through [`crate::pipeline::Engine`], whose
/// output is asserted bit-identical to this function for every worker
/// count.
pub fn quantize_model(ck: &Checkpoint, bits: Bits, method: &Method) -> Result<QuantizedModel> {
    let mut linears = BTreeMap::new();
    let mut fp_tensors = BTreeMap::new();
    let mut embedding = None;
    for info in param_inventory(&ck.config) {
        let t = ck.get(&info.name)?;
        match info.kind {
            ParamKind::Norm => {
                fp_tensors.insert(info.name.clone(), t.clone());
            }
            ParamKind::Embedding => {
                embedding = Some(quant::quantize_per_channel(t, bits));
            }
            ParamKind::Linear => {
                linears.insert(info.name.clone(), quantize_linear_param(t, bits, method));
            }
        }
    }
    Ok(QuantizedModel {
        config: ck.config.clone(),
        bits,
        method_name: method.name(),
        linears,
        embedding: embedding.ok_or_else(|| anyhow!("model has no embedding"))?,
        fp_tensors,
    })
}

impl QuantizedModel {
    /// Materialize the *effective* FP checkpoint (every weight replaced by
    /// its dequantized value). Running the reference forward on this is
    /// numerically identical to integer execution with dequant-on-load —
    /// the standard simulated-quantization evaluation.
    pub fn effective_checkpoint(&self) -> Checkpoint {
        let mut tensors = BTreeMap::new();
        tensors.insert("embed.tok".to_string(), self.embedding.dequantize());
        for (name, t) in &self.fp_tensors {
            tensors.insert(name.clone(), t.clone());
        }
        for (name, q) in &self.linears {
            tensors.insert(name.clone(), q.effective());
        }
        Checkpoint {
            config: self.config.clone(),
            tensors,
            meta: BTreeMap::from([
                ("quant_method".to_string(), self.method_name.clone()),
                ("bits".to_string(), self.bits.name().to_string()),
            ]),
        }
    }

    /// Packed artifact size in bytes: packed integer planes + FP norm
    /// gains + per-plane parameter overhead (scale f32 + zero i8 each).
    pub fn packed_bytes(&self) -> u64 {
        let linear: u64 = self.linears.values().map(|q| q.packed_len() as u64).sum();
        let emb = self.embedding.packed_len() as u64
            + self.embedding.params.len() as u64 * 5;
        let fp: u64 = self.fp_tensors.values().map(|t| t.len() as u64 * 4).sum();
        let plane_overhead: u64 = self
            .linears
            .values()
            .map(|q| q.n_planes() as u64 * 5)
            .sum();
        linear + emb + fp + plane_overhead
    }

    /// Total number of stored integer values (k× for split layers).
    pub fn stored_values(&self) -> u64 {
        let linear: u64 = self
            .linears
            .iter()
            .map(|(_, q)| match q {
                QuantParam::Plain(t) => t.plane.len() as u64,
                QuantParam::Split(s) => s.planes.iter().map(|p| p.plane.len() as u64).sum(),
                QuantParam::OcsEffective { effective, .. } => effective.len() as u64,
            })
            .sum();
        linear + self.embedding.plane.len() as u64
    }
}

/// Multi-core variant of [`quantize_model`]: every parameter's preprocess
/// job fans out over the worker pool through the layer-pipeline engine
/// ([`crate::pipeline::quantize_with_pool`]), which merges results in
/// inventory order behind a bounded reorder window. Results are
/// bit-identical to the sequential path for any pool size; on a 1-core
/// host it degrades to sequential execution.
pub fn quantize_model_parallel(
    ck: &Checkpoint,
    bits: Bits,
    method: &Method,
    pool: &crate::util::pool::Pool,
) -> Result<QuantizedModel> {
    crate::pipeline::quantize_with_pool(pool, ck, bits, method).map(|(qm, _report)| qm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{forward::Workspace, n_params, PicoLlamaConfig};
    use crate::util::stats::max_abs_diff;

    fn outlier_ck() -> Checkpoint {
        let mut ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 7);
        ck.amplify_outliers(0.002, 15.0, 8);
        ck
    }

    #[test]
    fn baseline_and_split_roundtrip_shapes() {
        let ck = outlier_ck();
        for method in [
            Method::Baseline,
            Method::SplitQuant(SplitConfig::default()),
            Method::Ocs { expand_ratio: 0.05 },
        ] {
            let qm = quantize_model(&ck, Bits::Int4, &method).unwrap();
            let eff = qm.effective_checkpoint();
            eff.validate().unwrap();
        }
    }

    #[test]
    fn split_eff_closer_to_fp_than_baseline() {
        let ck = outlier_ck();
        let base = quantize_model(&ck, Bits::Int4, &Method::Baseline)
            .unwrap()
            .effective_checkpoint();
        let split = quantize_model(
            &ck,
            Bits::Int4,
            &Method::SplitQuant(SplitConfig::default()),
        )
        .unwrap()
        .effective_checkpoint();
        // Aggregate weight-space error across all linear layers.
        let mut err_base = 0.0;
        let mut err_split = 0.0;
        for info in param_inventory(&ck.config) {
            if info.kind == ParamKind::Linear {
                let w = ck.get(&info.name).unwrap();
                err_base += crate::util::stats::mse(w.data(), base.get(&info.name).unwrap().data());
                err_split +=
                    crate::util::stats::mse(w.data(), split.get(&info.name).unwrap().data());
            }
        }
        assert!(
            err_split < err_base * 0.2,
            "split {err_split} vs baseline {err_base}"
        );
    }

    #[test]
    fn logits_closer_under_split() {
        let ck = outlier_ck();
        let mut ws = Workspace::new(&ck.config, 16);
        let toks = [1usize, 5, 9, 2];
        let fp = crate::model::forward::forward(&ck, &toks, &mut ws).unwrap();
        let base = quantize_model(&ck, Bits::Int4, &Method::Baseline)
            .unwrap()
            .effective_checkpoint();
        let split = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
            .unwrap()
            .effective_checkpoint();
        let lb = crate::model::forward::forward(&base, &toks, &mut ws).unwrap();
        let ls = crate::model::forward::forward(&split, &toks, &mut ws).unwrap();
        let db = max_abs_diff(fp.data(), lb.data());
        let ds = max_abs_diff(fp.data(), ls.data());
        assert!(ds < db, "split logit err {ds} vs baseline {db}");
    }

    #[test]
    fn packed_size_ratios_match_paper_section5() {
        // FP32 → INT4 baseline ≈ 1/8; INT4 split(k=3) ≈ 3/8 (§5).
        let cfg = PicoLlamaConfig::eval();
        let ck = Checkpoint::random_init(&cfg, 3);
        let fp_bytes = ck.fp32_bytes() as f64;
        let base = quantize_model(&ck, Bits::Int4, &Method::Baseline).unwrap();
        let split = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
            .unwrap();
        let r_base = base.packed_bytes() as f64 / fp_bytes;
        let r_split = split.packed_bytes() as f64 / fp_bytes;
        assert!((0.115..0.15).contains(&r_base), "baseline ratio {r_base}");
        // Embedding is not split, so the whole-model ratio sits between
        // 1/8 and 3/8 depending on the embedding share; linear-only ratio
        // is the paper's 3/8.
        assert!(r_split > r_base * 2.0, "split ratio {r_split}");
        let lin_base: u64 = base.linears.values().map(|q| q.packed_len() as u64).sum();
        let lin_split: u64 = split.linears.values().map(|q| q.packed_len() as u64).sum();
        assert_eq!(lin_split, 3 * lin_base, "linear planes are exactly 3x");
        let lin_fp: u64 = param_inventory(&cfg)
            .iter()
            .filter(|p| p.kind == ParamKind::Linear)
            .map(|p| p.numel() as u64 * 4)
            .sum();
        let ratio = lin_split as f64 / lin_fp as f64;
        assert!((ratio - 3.0 / 8.0).abs() < 0.01, "linear ratio {ratio} != 3/8");
    }

    #[test]
    fn stored_values_account_k_planes() {
        let ck = outlier_ck();
        let base = quantize_model(&ck, Bits::Int4, &Method::Baseline).unwrap();
        let split = quantize_model(&ck, Bits::Int4, &Method::SplitQuant(SplitConfig::default()))
            .unwrap();
        let n = n_params(&ck.config) as u64;
        assert!(base.stored_values() < n); // norms not stored as ints
        assert!(split.stored_values() > base.stored_values() * 2);
    }

    #[test]
    fn parallel_quantize_matches_sequential() {
        let ck = outlier_ck();
        let pool = crate::util::pool::Pool::new(3);
        for method in [
            Method::Baseline,
            Method::SplitQuant(SplitConfig::default()),
            Method::Ocs { expand_ratio: 0.03 },
        ] {
            let seq = quantize_model(&ck, Bits::Int4, &method).unwrap();
            let par = quantize_model_parallel(&ck, Bits::Int4, &method, &pool).unwrap();
            let a = seq.effective_checkpoint();
            let b = par.effective_checkpoint();
            for (name, t) in &a.tensors {
                assert_eq!(b.tensors.get(name).unwrap(), t, "{name}");
            }
            assert_eq!(seq.packed_bytes(), par.packed_bytes());
        }
    }

    #[test]
    fn method_names() {
        assert_eq!(Method::Baseline.name(), "baseline");
        assert_eq!(
            Method::SplitQuant(SplitConfig::default()).name(),
            "splitquantv2(k=3)"
        );
        assert!(Method::Ocs { expand_ratio: 0.1 }.name().starts_with("ocs"));
    }
}
