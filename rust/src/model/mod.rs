//! Model IR: the `picollama` transformer family (Llama-3 architecture —
//! RMSNorm, RoPE, grouped-query attention, SwiGLU) and the parameter
//! inventory that drives the quantization pipeline.
//!
//! The paper evaluates on Llama 3.2 1B Instruct; this crate substitutes a
//! configurable model of the *same architecture family* (see DESIGN.md
//! §3) whose layer inventory matches 1:1: per block `wq/wk/wv/wo` +
//! `gate/up/down`, token embedding, RMSNorm gains, LM head. Splitting
//! eligibility follows the paper's §3 rules: **linear layers are split;
//! embeddings (lookup tables) and normalization gains are not.**

pub mod decode;
pub mod forward;
pub mod packed;
pub mod quantized;
pub mod specdec;

use std::collections::BTreeMap;

use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Architecture hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct PicoLlamaConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Grouped-query attention: number of KV heads (divides `n_heads`).
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    /// Share the embedding matrix with the LM head (Llama 3.2 1B does).
    pub tie_embeddings: bool,
}

impl PicoLlamaConfig {
    /// Tiny config for unit tests (sub-second everything).
    pub fn test() -> Self {
        Self {
            vocab: 96,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 64,
            max_seq: 32,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
            tie_embeddings: true,
        }
    }

    /// The evaluation model (~0.8M params): its vocab matches the
    /// synthetic-arc world of `python/compile/datagen.py`
    /// (5 special + 120 entities + 6 attributes + 80 values = 211);
    /// large enough to learn the fact world and show quantization
    /// degradation, small enough to sweep INT2/4/8 × all arms quickly.
    pub fn eval() -> Self {
        Self {
            vocab: 211,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 352,
            max_seq: 64,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
            tie_embeddings: true,
        }
    }

    /// Llama-3.2-1B-shaped config (for size/time accounting benches; not
    /// trained here).
    pub fn llama32_1b() -> Self {
        Self {
            vocab: 128_256,
            d_model: 2048,
            n_layers: 16,
            n_heads: 32,
            n_kv_heads: 8,
            d_ff: 8192,
            max_seq: 131_072,
            rope_theta: 500_000.0,
            norm_eps: 1e-5,
            tie_embeddings: true,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_model % self.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        if self.n_heads % self.n_kv_heads != 0 {
            bail!("n_heads {} not divisible by n_kv_heads {}", self.n_heads, self.n_kv_heads);
        }
        if self.head_dim() % 2 != 0 {
            bail!("head_dim {} must be even for RoPE", self.head_dim());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::num(self.vocab as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("n_kv_heads", Json::num(self.n_kv_heads as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("rope_theta", Json::num(self.rope_theta)),
            ("norm_eps", Json::num(self.norm_eps)),
            ("tie_embeddings", Json::Bool(self.tie_embeddings)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow!("config field '{k}' must be an unsigned integer"))
        };
        let f = |k: &str| -> Result<f64> {
            j.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow!("config field '{k}' must be a number"))
        };
        let c = Self {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            d_ff: u("d_ff")?,
            max_seq: u("max_seq")?,
            rope_theta: f("rope_theta")?,
            norm_eps: f("norm_eps")?,
            tie_embeddings: j
                .req("tie_embeddings")?
                .as_bool()
                .ok_or_else(|| anyhow!("tie_embeddings must be bool"))?,
        };
        c.validate()?;
        Ok(c)
    }
}

/// What a parameter *is* — drives split eligibility (§3 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// Linear weight matrix `[out, in]` — split + quantized.
    Linear,
    /// Embedding lookup table — quantized (rows are looked up, ranges are
    /// benign) but never split.
    Embedding,
    /// Normalization gain vector — kept in FP (negligible size).
    Norm,
}

impl ParamKind {
    /// Per the paper's §3: only linear (and conv) layers are split.
    pub fn splittable(self) -> bool {
        matches!(self, ParamKind::Linear)
    }
}

/// One entry of the model's parameter inventory.
#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: ParamKind,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Full parameter inventory in a canonical order.
pub fn param_inventory(cfg: &PicoLlamaConfig) -> Vec<ParamInfo> {
    let mut v = Vec::new();
    let p = |name: String, shape: Vec<usize>, kind: ParamKind| ParamInfo { name, shape, kind };
    v.push(p("embed.tok".into(), vec![cfg.vocab, cfg.d_model], ParamKind::Embedding));
    for l in 0..cfg.n_layers {
        let pre = format!("layers.{l}");
        v.push(p(format!("{pre}.norm_attn"), vec![cfg.d_model], ParamKind::Norm));
        v.push(p(format!("{pre}.attn.wq"), vec![cfg.d_model, cfg.d_model], ParamKind::Linear));
        v.push(p(format!("{pre}.attn.wk"), vec![cfg.kv_dim(), cfg.d_model], ParamKind::Linear));
        v.push(p(format!("{pre}.attn.wv"), vec![cfg.kv_dim(), cfg.d_model], ParamKind::Linear));
        v.push(p(format!("{pre}.attn.wo"), vec![cfg.d_model, cfg.d_model], ParamKind::Linear));
        v.push(p(format!("{pre}.norm_mlp"), vec![cfg.d_model], ParamKind::Norm));
        v.push(p(format!("{pre}.mlp.gate"), vec![cfg.d_ff, cfg.d_model], ParamKind::Linear));
        v.push(p(format!("{pre}.mlp.up"), vec![cfg.d_ff, cfg.d_model], ParamKind::Linear));
        v.push(p(format!("{pre}.mlp.down"), vec![cfg.d_model, cfg.d_ff], ParamKind::Linear));
    }
    v.push(p("norm.final".into(), vec![cfg.d_model], ParamKind::Norm));
    if !cfg.tie_embeddings {
        v.push(p("lm_head".into(), vec![cfg.vocab, cfg.d_model], ParamKind::Linear));
    }
    v
}

/// Total parameter count.
pub fn n_params(cfg: &PicoLlamaConfig) -> usize {
    param_inventory(cfg).iter().map(|p| p.numel()).sum()
}

/// A floating-point model: config + named tensors.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub config: PicoLlamaConfig,
    pub tensors: BTreeMap<String, Tensor>,
    pub meta: BTreeMap<String, String>,
}

impl Checkpoint {
    /// Validate every inventory entry is present with the right shape.
    pub fn validate(&self) -> Result<()> {
        self.config.validate()?;
        for info in param_inventory(&self.config) {
            let t = self
                .tensors
                .get(&info.name)
                .ok_or_else(|| anyhow!("missing tensor '{}'", info.name))?;
            if t.shape() != info.shape.as_slice() {
                bail!(
                    "tensor '{}' shape {:?} != expected {:?}",
                    info.name,
                    t.shape(),
                    info.shape
                );
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("missing tensor '{name}'"))
    }

    /// Random-init model (He-style scaled normals) — used by tests and by
    /// the synthetic timing benches; the *trained* eval checkpoint comes
    /// from python/compile/train.py via SQTZ.
    pub fn random_init(cfg: &PicoLlamaConfig, seed: u64) -> Checkpoint {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut tensors = BTreeMap::new();
        for info in param_inventory(cfg) {
            let t = match info.kind {
                ParamKind::Norm => Tensor::full(&info.shape, 1.0),
                _ => {
                    let fan_in = *info.shape.last().unwrap() as f32;
                    let std = (2.0 / fan_in).sqrt().min(0.08);
                    let mut data = vec![0.0f32; info.numel()];
                    rng.fill_normal(&mut data, 0.0, std);
                    Tensor::new(&info.shape, data)
                }
            };
            tensors.insert(info.name, t);
        }
        Checkpoint {
            config: cfg.clone(),
            tensors,
            meta: BTreeMap::new(),
        }
    }

    /// Amplify weight outliers (DESIGN.md §3 substitution: recreate the
    /// LLM-scale outlier regime on a small trained model). Scales the
    /// largest `frac` fraction of |values| in every *linear* tensor by
    /// `gain`. Returns the number of values touched.
    pub fn amplify_outliers(&mut self, frac: f64, gain: f32, seed: u64) -> usize {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut touched = 0;
        for info in param_inventory(&self.config) {
            if info.kind != ParamKind::Linear {
                continue;
            }
            let t = self.tensors.get_mut(&info.name).unwrap();
            let n = t.len();
            let n_amp = ((n as f64 * frac).ceil() as usize).max(1).min(n);
            // Find the magnitude threshold of the top-n_amp values.
            let mut mags: Vec<f32> = t.data().iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let thresh = mags[n_amp - 1];
            for v in t.data_mut().iter_mut() {
                if v.abs() >= thresh && touched < usize::MAX {
                    // Slight jitter so amplified values do not collide.
                    *v *= gain * rng.uniform_in(0.9, 1.1);
                    touched += 1;
                }
            }
        }
        touched
    }

    /// Bytes of an FP32 export (E4 size table baseline).
    pub fn fp32_bytes(&self) -> u64 {
        self.tensors.values().map(|t| t.len() as u64 * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_validate() {
        for c in [
            PicoLlamaConfig::test(),
            PicoLlamaConfig::eval(),
            PicoLlamaConfig::llama32_1b(),
        ] {
            c.validate().unwrap();
        }
    }

    #[test]
    fn llama_1b_param_count_is_about_1b() {
        // Real Llama 3.2 1B has ~1.24B params; the shape clone must land
        // in the same ballpark (tied embeddings).
        let n = n_params(&PicoLlamaConfig::llama32_1b());
        assert!(
            (1_100_000_000..1_400_000_000).contains(&n),
            "n_params = {n}"
        );
    }

    #[test]
    fn inventory_kinds() {
        let cfg = PicoLlamaConfig::test();
        let inv = param_inventory(&cfg);
        let linear = inv.iter().filter(|p| p.kind == ParamKind::Linear).count();
        let norm = inv.iter().filter(|p| p.kind == ParamKind::Norm).count();
        let emb = inv.iter().filter(|p| p.kind == ParamKind::Embedding).count();
        assert_eq!(linear, cfg.n_layers * 7); // q,k,v,o,gate,up,down
        assert_eq!(norm, cfg.n_layers * 2 + 1);
        assert_eq!(emb, 1);
        assert!(ParamKind::Linear.splittable());
        assert!(!ParamKind::Embedding.splittable());
        assert!(!ParamKind::Norm.splittable());
    }

    #[test]
    fn random_init_validates() {
        let cfg = PicoLlamaConfig::test();
        let ck = Checkpoint::random_init(&cfg, 1);
        ck.validate().unwrap();
        assert_eq!(ck.fp32_bytes(), n_params(&cfg) as u64 * 4);
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = PicoLlamaConfig::eval();
        let j = cfg.to_json();
        let back = PicoLlamaConfig::from_json(&j).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = PicoLlamaConfig::test();
        c.n_heads = 3; // does not divide d_model=32
        assert!(c.validate().is_err());
        let mut c = PicoLlamaConfig::test();
        c.n_kv_heads = 3; // does not divide n_heads=4
        assert!(c.validate().is_err());
    }

    #[test]
    fn amplify_outliers_touches_linear_only() {
        let cfg = PicoLlamaConfig::test();
        let mut ck = Checkpoint::random_init(&cfg, 2);
        let emb_before = ck.get("embed.tok").unwrap().clone();
        let norm_before = ck.get("layers.0.norm_attn").unwrap().clone();
        let touched = ck.amplify_outliers(0.001, 20.0, 3);
        assert!(touched > 0);
        assert_eq!(ck.get("embed.tok").unwrap(), &emb_before);
        assert_eq!(ck.get("layers.0.norm_attn").unwrap(), &norm_before);
        // Linear absmax grew.
        let w = ck.get("layers.0.attn.wq").unwrap();
        let w0 = Checkpoint::random_init(&cfg, 2);
        assert!(w.abs_max() > w0.get("layers.0.attn.wq").unwrap().abs_max() * 5.0);
    }

    #[test]
    fn missing_tensor_fails_validation() {
        let cfg = PicoLlamaConfig::test();
        let mut ck = Checkpoint::random_init(&cfg, 1);
        ck.tensors.remove("layers.0.attn.wq");
        assert!(ck.validate().is_err());
    }

    #[test]
    fn wrong_shape_fails_validation() {
        let cfg = PicoLlamaConfig::test();
        let mut ck = Checkpoint::random_init(&cfg, 1);
        ck.tensors
            .insert("layers.0.attn.wq".into(), Tensor::zeros(&[2, 2]));
        assert!(ck.validate().is_err());
    }
}
