//! Resumable decode state: the per-layer K/V caches behind
//! `forward::forward_extend`, plus the bounded LRU prompt-prefix cache
//! the serving path builds on its snapshots.
//!
//! A [`DecodeState`] makes the transformer forward *incremental*: the
//! K/V rows of every position computed so far persist across calls, so
//! extending a sequence by `m` tokens costs `m` position-forwards
//! instead of re-running the whole prefix. Rollback is O(1) — the state
//! keeps a logical length and truncating it simply rewinds that cursor
//! (the cached rows are overwritten by the next extension) — which is
//! what lets MCQ scoring replay N option continuations against one
//! computed prompt.
//!
//! [`PrefixCache`] extends the reuse *across requests*: a bounded LRU
//! from prompt token ids to a compact [`DecodeState`] snapshot plus the
//! prompt's last-position logits row. Concurrent server workers that
//! score problems sharing a prompt copy the cached K/V instead of
//! recomputing it. Entries are `Arc`-shared so a lookup is a pointer
//! clone under the lock; the K/V payload is copied outside it.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::PicoLlamaConfig;

/// Per-layer K/V cache with O(1) truncation (snapshot/rollback).
///
/// Layout: one `Vec<f32>` of `[len, kv_dim]` rows per layer. The
/// physical vectors only grow; `len` is the logical number of cached
/// positions and everything beyond it is dead until overwritten by the
/// next [`append_layer`](DecodeState::append_layer).
#[derive(Clone, Debug)]
pub struct DecodeState {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    kv_dim: usize,
    max_seq: usize,
    len: usize,
}

impl DecodeState {
    /// Empty state for a model config. Buffers grow lazily up to
    /// `max_seq` positions, so constructing one is allocation-light.
    pub fn new(cfg: &PicoLlamaConfig) -> DecodeState {
        DecodeState {
            k: vec![Vec::new(); cfg.n_layers],
            v: vec![Vec::new(); cfg.n_layers],
            kv_dim: cfg.kv_dim(),
            max_seq: cfg.max_seq,
            len: 0,
        }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum position capacity (the model's `max_seq`).
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Rewind to `len` cached positions (O(1): later rows stay in the
    /// buffers until the next extension overwrites them). This is the
    /// rollback half of snapshot/rollback scoring.
    pub fn truncate(&mut self, len: usize) {
        assert!(
            len <= self.len,
            "truncate to {len} but only {} positions cached",
            self.len
        );
        self.len = len;
    }

    /// Drop every cached position.
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes of live K/V payload (cache accounting).
    pub fn kv_bytes(&self) -> usize {
        2 * self.k.len() * self.len * self.kv_dim * 4
    }

    /// Compact copy of the first `len` positions (the snapshot half of
    /// snapshot/rollback; what the prefix cache stores).
    pub fn snapshot(&self, len: usize) -> DecodeState {
        assert!(len <= self.len, "snapshot of {len} > cached {}", self.len);
        let n = len * self.kv_dim;
        DecodeState {
            k: self.k.iter().map(|kl| kl[..n].to_vec()).collect(),
            v: self.v.iter().map(|vl| vl[..n].to_vec()).collect(),
            kv_dim: self.kv_dim,
            max_seq: self.max_seq,
            len,
        }
    }

    /// Overwrite this state with `other`'s cached positions, reusing
    /// this state's allocations (the cache-hit restore path).
    pub fn copy_from(&mut self, other: &DecodeState) {
        assert_eq!(self.kv_dim, other.kv_dim, "kv_dim mismatch");
        assert_eq!(self.k.len(), other.k.len(), "layer count mismatch");
        let n = other.len * other.kv_dim;
        for (dst, src) in self.k.iter_mut().zip(&other.k) {
            dst.clear();
            dst.extend_from_slice(&src[..n]);
        }
        for (dst, src) in self.v.iter_mut().zip(&other.v) {
            dst.clear();
            dst.extend_from_slice(&src[..n]);
        }
        self.len = other.len;
    }

    /// Write one layer's K/V rows for positions `start..start+m` (the
    /// chunk being extended). Overwrites anything previously cached at
    /// or after `start`; the caller commits the new logical length once
    /// every layer has been written.
    pub(crate) fn append_layer(&mut self, l: usize, start: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), v.len());
        debug_assert_eq!(k.len() % self.kv_dim, 0);
        let base = start * self.kv_dim;
        debug_assert!(base <= self.k[l].len(), "append past cached prefix");
        self.k[l].truncate(base);
        self.k[l].extend_from_slice(k);
        self.v[l].truncate(base);
        self.v[l].extend_from_slice(v);
    }

    /// One layer's cached K/V for positions `0..upto` (row-major
    /// `[upto, kv_dim]` slices).
    pub(crate) fn layer_kv(&self, l: usize, upto: usize) -> (&[f32], &[f32]) {
        let n = upto * self.kv_dim;
        (&self.k[l][..n], &self.v[l][..n])
    }

    /// Commit the logical length after an extension wrote all layers.
    pub(crate) fn commit(&mut self, len: usize) {
        debug_assert!(len <= self.max_seq);
        self.len = len;
    }
}

/// One cached prompt: its decode state (exactly `prompt.len()` cached
/// positions) and the prompt's last-position logits row — everything a
/// worker needs to score option continuations without re-running the
/// prompt.
#[derive(Clone, Debug)]
pub struct PrefixEntry {
    pub state: DecodeState,
    pub last_row: Vec<f32>,
}

impl PrefixEntry {
    pub fn new(state: DecodeState, last_row: Vec<f32>) -> PrefixEntry {
        PrefixEntry { state, last_row }
    }
}

/// Bounded LRU from prompt token ids to [`PrefixEntry`]. Capacity 0
/// disables the cache (every lookup misses, inserts are dropped), so
/// callers never need a separate on/off switch.
#[derive(Debug)]
pub struct PrefixCache {
    cap: usize,
    tick: u64,
    map: BTreeMap<Vec<usize>, (u64, Arc<PrefixEntry>)>,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    pub fn new(cap: usize) -> PrefixCache {
        PrefixCache {
            cap,
            tick: 0,
            map: BTreeMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Look up a prompt, refreshing its recency on hit.
    pub fn get(&mut self, prompt: &[usize]) -> Option<Arc<PrefixEntry>> {
        if self.cap == 0 {
            return None;
        }
        match self.map.get_mut(prompt) {
            Some(slot) => {
                self.tick += 1;
                slot.0 = self.tick;
                self.hits += 1;
                Some(Arc::clone(&slot.1))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a prompt's entry, evicting the
    /// least-recently-used entry when at capacity.
    pub fn insert(&mut self, prompt: Vec<usize>, entry: PrefixEntry) {
        if self.cap == 0 {
            return;
        }
        if !self.map.contains_key(&prompt) && self.map.len() >= self.cap {
            let oldest = self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| k.clone());
            if let Some(key) = oldest {
                self.map.remove(&key);
            }
        }
        self.tick += 1;
        self.map.insert(prompt, (self.tick, Arc::new(entry)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PicoLlamaConfig {
        PicoLlamaConfig::test()
    }

    fn state_with(cfg: &PicoLlamaConfig, positions: usize, fill: f32) -> DecodeState {
        let mut st = DecodeState::new(cfg);
        let kvd = cfg.kv_dim();
        for l in 0..cfg.n_layers {
            let rows = vec![fill; positions * kvd];
            st.append_layer(l, 0, &rows, &rows);
        }
        st.commit(positions);
        st
    }

    #[test]
    fn truncate_is_logical_and_reextendable() {
        let cfg = cfg();
        let kvd = cfg.kv_dim();
        let mut st = state_with(&cfg, 5, 1.0);
        assert_eq!(st.len(), 5);
        assert_eq!(st.kv_bytes(), 2 * cfg.n_layers * 5 * kvd * 4);
        st.truncate(2);
        assert_eq!(st.len(), 2);
        // Re-extend over the truncated tail with different values.
        for l in 0..cfg.n_layers {
            let rows = vec![7.0; 3 * kvd];
            st.append_layer(l, 2, &rows, &rows);
        }
        st.commit(5);
        let (k, _) = st.layer_kv(0, 5);
        assert_eq!(k[0], 1.0, "prefix preserved");
        assert_eq!(k[2 * kvd], 7.0, "tail overwritten");
    }

    #[test]
    fn snapshot_and_copy_from_roundtrip() {
        let cfg = cfg();
        let st = state_with(&cfg, 4, 3.0);
        let snap = st.snapshot(3);
        assert_eq!(snap.len(), 3);
        let mut other = state_with(&cfg, 6, 9.0);
        other.copy_from(&snap);
        assert_eq!(other.len(), 3);
        let (k, v) = other.layer_kv(1, 3);
        assert!(k.iter().chain(v).all(|&x| x == 3.0));
    }

    #[test]
    #[should_panic(expected = "truncate")]
    fn truncate_beyond_len_panics() {
        let mut st = DecodeState::new(&cfg());
        st.truncate(1);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let cfg = cfg();
        let entry = || PrefixEntry::new(DecodeState::new(&cfg), vec![0.0]);
        let mut cache = PrefixCache::new(2);
        cache.insert(vec![1], entry());
        cache.insert(vec![2], entry());
        assert!(cache.get(&[1]).is_some()); // refresh [1]; [2] is now LRU
        cache.insert(vec![3], entry());
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&[2]).is_none(), "LRU entry evicted");
        assert!(cache.get(&[1]).is_some());
        assert!(cache.get(&[3]).is_some());
        assert_eq!(cache.hits(), 3);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn zero_capacity_disables_cache() {
        let cfg = cfg();
        let mut cache = PrefixCache::new(0);
        cache.insert(vec![1], PrefixEntry::new(DecodeState::new(&cfg), vec![]));
        assert!(cache.get(&[1]).is_none());
        assert!(cache.is_empty());
        assert_eq!(cache.capacity(), 0);
    }

    #[test]
    fn reinsert_refreshes_existing_key_without_evicting() {
        let cfg = cfg();
        let entry = |x: f32| PrefixEntry::new(DecodeState::new(&cfg), vec![x]);
        let mut cache = PrefixCache::new(2);
        cache.insert(vec![1], entry(1.0));
        cache.insert(vec![2], entry(2.0));
        cache.insert(vec![1], entry(10.0)); // refresh, no eviction
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get(&[1]).unwrap().last_row, vec![10.0]);
        assert!(cache.get(&[2]).is_some());
    }
}
