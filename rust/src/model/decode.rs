//! Resumable decode state: the per-layer K/V caches behind
//! `forward::forward_extend`, plus the bounded LRU prompt-prefix cache
//! the serving path builds on its snapshots.
//!
//! A [`DecodeState`] makes the transformer forward *incremental*: the
//! K/V rows of every position computed so far persist across calls, so
//! extending a sequence by `m` tokens costs `m` position-forwards
//! instead of re-running the whole prefix. Rollback is O(1) — the state
//! keeps a logical length and truncating it simply rewinds that cursor
//! (the cached rows are overwritten by the next extension) — which is
//! what lets MCQ scoring replay N option continuations against one
//! computed prompt.
//!
//! Two storage backings sit behind the same API. The *owned* backing
//! (the original) keeps one contiguous `Vec<f32>` of `[len, kv_dim]`
//! rows per layer — ideal for a handful of long-lived states. The
//! *paged* backing rents fixed-size blocks from a shared [`KvArena`]
//! so thousands of concurrent generation sessions share one bounded
//! pool: a session holding 3 cached positions pins one block, not a
//! `max_seq`-sized buffer, and cancelling it returns its blocks to the
//! pool immediately (on drop). The forward path reads the cache through
//! per-position row accessors ([`k_row`](DecodeState::k_row) /
//! [`v_row`](DecodeState::v_row)) whose float layout within a row is
//! identical for both backings, which is what keeps paged decode
//! bit-identical to contiguous decode.
//!
//! [`PrefixCache`] extends the reuse *across requests*: a bounded LRU
//! from prompt token ids to a compact [`DecodeState`] snapshot plus the
//! prompt's last-position logits row. Concurrent server workers that
//! score problems sharing a prompt copy the cached K/V instead of
//! recomputing it. Entries are `Arc`-shared so a lookup is a pointer
//! clone under the lock; the K/V payload is copied outside it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::PicoLlamaConfig;
use crate::obs;

/// Telemetry handles for the arena and the prefix cache, looked up
/// once. The occupancy gauge tracks whichever arena transitioned last;
/// a serving process has exactly one.
struct DecodeMetrics {
    kv_in_use: obs::Gauge,
    kv_failures: obs::Counter,
    cache_hits: obs::Counter,
    cache_misses: obs::Counter,
}

fn metrics() -> &'static DecodeMetrics {
    static M: OnceLock<DecodeMetrics> = OnceLock::new();
    M.get_or_init(|| DecodeMetrics {
        kv_in_use: obs::gauge(obs::names::KV_BLOCKS_IN_USE),
        kv_failures: obs::counter(obs::names::KV_RESERVATION_FAILURES),
        cache_hits: obs::counter(obs::names::PREFIX_CACHE_HITS),
        cache_misses: obs::counter(obs::names::PREFIX_CACHE_MISSES),
    })
}

/// A paged state could not rent enough blocks from its [`KvArena`].
///
/// Surfaced to the serving layer as a typed admission failure (shed the
/// request) rather than a panic: the arena being full is an expected
/// overload condition, not a bug.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvArenaExhausted {
    /// Blocks still needed beyond what the state already holds.
    pub requested: usize,
    /// Total blocks the arena can ever hand out.
    pub total: usize,
}

impl std::fmt::Display for KvArenaExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV arena exhausted: {} more block(s) requested, {} total in pool",
            self.requested, self.total
        )
    }
}

impl std::error::Error for KvArenaExhausted {}

/// Shared pool of fixed-size K/V blocks (the paged-attention slab).
///
/// One block stores `block_positions` positions of K *and* V for every
/// layer, laid out `[layer][k|v][position][kv_dim]`, so renting blocks
/// is the only allocation decision a session ever makes — no per-layer
/// bookkeeping. Blocks are created lazily up to `total_blocks` and then
/// recycled through a free list; occupancy is readable lock-free via
/// [`blocks_in_use`](KvArena::blocks_in_use), which is what the serving
/// tests use to prove cancellation returns memory.
#[derive(Debug)]
pub struct KvArena {
    n_layers: usize,
    kv_dim: usize,
    block_positions: usize,
    block_floats: usize,
    total_blocks: usize,
    created: AtomicUsize,
    in_use: AtomicUsize,
    free: Mutex<Vec<Box<[f32]>>>,
}

impl KvArena {
    /// Pool for `cfg`'s geometry: `total_blocks` blocks of
    /// `block_positions` positions each.
    pub fn new(cfg: &PicoLlamaConfig, block_positions: usize, total_blocks: usize) -> KvArena {
        assert!(block_positions > 0, "block_positions must be positive");
        KvArena {
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            block_positions,
            block_floats: cfg.n_layers * 2 * block_positions * cfg.kv_dim(),
            total_blocks,
            created: AtomicUsize::new(0),
            in_use: AtomicUsize::new(0),
            free: Mutex::new(Vec::new()),
        }
    }

    /// Positions one block holds.
    pub fn block_positions(&self) -> usize {
        self.block_positions
    }

    /// Total blocks the pool can hand out.
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks currently rented by live states (lock-free read).
    pub fn blocks_in_use(&self) -> usize {
        self.in_use.load(Ordering::SeqCst)
    }

    /// Blocks needed to cache `positions` positions.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_positions)
    }

    /// Payload bytes of one block.
    pub fn block_bytes(&self) -> usize {
        self.block_floats * 4
    }

    /// Rent one block: recycle from the free list, else create lazily
    /// while under the cap. `None` means the pool is exhausted.
    fn alloc(&self) -> Option<Box<[f32]>> {
        if crate::util::failpoint::trigger(crate::util::failpoint::sites::ARENA_RESERVE).is_some() {
            // Injected exhaustion: report it exactly like a full pool.
            metrics().kv_failures.inc();
            return None;
        }
        if let Some(b) = self.free.lock().unwrap_or_else(|e| e.into_inner()).pop() {
            self.in_use.fetch_add(1, Ordering::SeqCst);
            self.note_occupancy();
            return Some(b);
        }
        loop {
            let created = self.created.load(Ordering::SeqCst);
            if created >= self.total_blocks {
                metrics().kv_failures.inc();
                return None;
            }
            if self
                .created
                .compare_exchange(created, created + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                self.in_use.fetch_add(1, Ordering::SeqCst);
                self.note_occupancy();
                return Some(vec![0.0f32; self.block_floats].into_boxed_slice());
            }
        }
    }

    /// Return a rented block to the free list.
    fn release(&self, block: Box<[f32]>) {
        // Runs from `Drop` (possibly mid-unwind): the failpoint is soft,
        // an injected error is ignored, and only `delay` is observable.
        // The occupancy decrement below is unconditional either way —
        // a fault here must never leak accounting.
        let _ = crate::util::failpoint::trigger_soft(crate::util::failpoint::sites::ARENA_RELEASE);
        self.free.lock().unwrap_or_else(|e| e.into_inner()).push(block);
        self.in_use.fetch_sub(1, Ordering::SeqCst);
        self.note_occupancy();
    }

    /// Mirror the occupancy counter into the telemetry gauge (its peak
    /// is the arena's high-water mark).
    fn note_occupancy(&self) {
        metrics()
            .kv_in_use
            .set(self.in_use.load(Ordering::SeqCst) as i64);
    }
}

/// Storage behind a [`DecodeState`]: contiguous per-layer vectors, or
/// blocks rented from a shared [`KvArena`].
#[derive(Debug)]
enum Backing {
    Owned {
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    },
    Paged {
        arena: Arc<KvArena>,
        blocks: Vec<Box<[f32]>>,
    },
}

/// Per-layer K/V cache with O(1) truncation (snapshot/rollback).
///
/// Owned layout: one `Vec<f32>` of `[len, kv_dim]` rows per layer.
/// Paged layout: rented [`KvArena`] blocks, position `p` living in
/// block `p / block_positions`. Either way the physical storage only
/// grows; `len` is the logical number of cached positions and
/// everything beyond it is dead until overwritten by the next
/// [`append_layer`](DecodeState::append_layer).
#[derive(Debug)]
pub struct DecodeState {
    backing: Backing,
    n_layers: usize,
    kv_dim: usize,
    max_seq: usize,
    len: usize,
}

impl DecodeState {
    /// Empty owned state for a model config. Buffers grow lazily up to
    /// `max_seq` positions, so constructing one is allocation-light.
    pub fn new(cfg: &PicoLlamaConfig) -> DecodeState {
        DecodeState {
            backing: Backing::Owned {
                k: vec![Vec::new(); cfg.n_layers],
                v: vec![Vec::new(); cfg.n_layers],
            },
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            max_seq: cfg.max_seq,
            len: 0,
        }
    }

    /// Empty paged state renting its storage from `arena`. Blocks are
    /// rented by [`reserve`](DecodeState::reserve) and returned when
    /// the state is dropped.
    pub fn paged(cfg: &PicoLlamaConfig, arena: Arc<KvArena>) -> DecodeState {
        assert_eq!(arena.n_layers, cfg.n_layers, "arena layer count mismatch");
        assert_eq!(arena.kv_dim, cfg.kv_dim(), "arena kv_dim mismatch");
        DecodeState {
            backing: Backing::Paged {
                arena,
                blocks: Vec::new(),
            },
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            max_seq: cfg.max_seq,
            len: 0,
        }
    }

    /// Whether this state rents blocks from a [`KvArena`].
    pub fn is_paged(&self) -> bool {
        matches!(self.backing, Backing::Paged { .. })
    }

    /// Blocks currently rented (0 for owned states).
    pub fn blocks_held(&self) -> usize {
        match &self.backing {
            Backing::Owned { .. } => 0,
            Backing::Paged { blocks, .. } => blocks.len(),
        }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum position capacity (the model's `max_seq`).
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Ensure storage exists for `positions` cached positions (clamped
    /// to `max_seq`). A no-op for owned states, which grow on append;
    /// paged states rent the missing blocks here — and keep whatever
    /// they already hold on failure, so a shed request can retry.
    pub fn reserve(&mut self, positions: usize) -> Result<(), KvArenaExhausted> {
        let positions = positions.min(self.max_seq);
        match &mut self.backing {
            Backing::Owned { .. } => Ok(()),
            Backing::Paged { arena, blocks } => {
                let needed = arena.blocks_for(positions);
                while blocks.len() < needed {
                    match arena.alloc() {
                        Some(b) => blocks.push(b),
                        None => {
                            return Err(KvArenaExhausted {
                                requested: needed - blocks.len(),
                                total: arena.total_blocks,
                            })
                        }
                    }
                }
                Ok(())
            }
        }
    }

    /// Rewind to `len` cached positions (O(1): later rows stay in the
    /// buffers until the next extension overwrites them). This is the
    /// rollback half of snapshot/rollback scoring.
    pub fn truncate(&mut self, len: usize) {
        assert!(
            len <= self.len,
            "truncate to {len} but only {} positions cached",
            self.len
        );
        self.len = len;
    }

    /// Drop every cached position. Paged states keep their rented
    /// blocks for reuse; drop the state to return them.
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes of live K/V payload (cache accounting).
    pub fn kv_bytes(&self) -> usize {
        2 * self.n_layers * self.len * self.kv_dim * 4
    }

    /// Compact *owned* copy of the first `len` positions (the snapshot
    /// half of snapshot/rollback; what the prefix cache stores). Paged
    /// states snapshot to owned storage, so snapshots never pin arena
    /// blocks.
    pub fn snapshot(&self, len: usize) -> DecodeState {
        assert!(len <= self.len, "snapshot of {len} > cached {}", self.len);
        let mut k = Vec::with_capacity(self.n_layers);
        let mut v = Vec::with_capacity(self.n_layers);
        for l in 0..self.n_layers {
            let mut kl = Vec::with_capacity(len * self.kv_dim);
            let mut vl = Vec::with_capacity(len * self.kv_dim);
            for p in 0..len {
                kl.extend_from_slice(self.k_row(l, p));
                vl.extend_from_slice(self.v_row(l, p));
            }
            k.push(kl);
            v.push(vl);
        }
        DecodeState {
            backing: Backing::Owned { k, v },
            n_layers: self.n_layers,
            kv_dim: self.kv_dim,
            max_seq: self.max_seq,
            len,
        }
    }

    /// Overwrite this state with `other`'s cached positions, reusing
    /// this state's allocations (the cache-hit restore path). Works
    /// across backings; a paged destination rents blocks as needed.
    pub fn copy_from(&mut self, other: &DecodeState) {
        assert_eq!(self.kv_dim, other.kv_dim, "kv_dim mismatch");
        assert_eq!(self.n_layers, other.n_layers, "layer count mismatch");
        self.len = 0;
        self.reserve(other.len)
            .expect("KV arena exhausted restoring a snapshot");
        let kvd = self.kv_dim;
        match &mut self.backing {
            Backing::Owned { k, v } => {
                for l in 0..other.n_layers {
                    k[l].clear();
                    v[l].clear();
                    for p in 0..other.len {
                        k[l].extend_from_slice(other.k_row(l, p));
                        v[l].extend_from_slice(other.v_row(l, p));
                    }
                }
            }
            Backing::Paged { arena, blocks } => {
                let bp = arena.block_positions;
                for l in 0..other.n_layers {
                    for p in 0..other.len {
                        let kb = ((l * 2) * bp + (p % bp)) * kvd;
                        blocks[p / bp][kb..kb + kvd].copy_from_slice(other.k_row(l, p));
                        let vb = ((l * 2 + 1) * bp + (p % bp)) * kvd;
                        blocks[p / bp][vb..vb + kvd].copy_from_slice(other.v_row(l, p));
                    }
                }
            }
        }
        self.len = other.len;
    }

    /// Write one layer's K/V rows for positions `start..start+m` (the
    /// chunk being extended). Overwrites anything previously cached at
    /// or after `start`; the caller commits the new logical length once
    /// every layer has been written. Paged callers must have
    /// [`reserve`](DecodeState::reserve)d `start + m` positions first.
    pub(crate) fn append_layer(&mut self, l: usize, start: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), v.len());
        debug_assert_eq!(k.len() % self.kv_dim, 0);
        let kvd = self.kv_dim;
        match &mut self.backing {
            Backing::Owned { k: ks, v: vs } => {
                let base = start * kvd;
                debug_assert!(base <= ks[l].len(), "append past cached prefix");
                ks[l].truncate(base);
                ks[l].extend_from_slice(k);
                vs[l].truncate(base);
                vs[l].extend_from_slice(v);
            }
            Backing::Paged { arena, blocks } => {
                let bp = arena.block_positions;
                let m = k.len() / kvd;
                assert!(
                    blocks.len() * bp >= start + m,
                    "append_layer without reserve: {} blocks hold {} positions, need {}",
                    blocks.len(),
                    blocks.len() * bp,
                    start + m
                );
                for i in 0..m {
                    let p = start + i;
                    let kb = ((l * 2) * bp + (p % bp)) * kvd;
                    blocks[p / bp][kb..kb + kvd].copy_from_slice(&k[i * kvd..(i + 1) * kvd]);
                    let vb = ((l * 2 + 1) * bp + (p % bp)) * kvd;
                    blocks[p / bp][vb..vb + kvd].copy_from_slice(&v[i * kvd..(i + 1) * kvd]);
                }
            }
        }
    }

    /// One cached K row (`kv_dim` floats) for layer `l`, position `p`.
    /// Identical float layout for both backings — the attention loop
    /// reads through this, which is what makes paged ≡ contiguous.
    #[inline]
    pub(crate) fn k_row(&self, l: usize, p: usize) -> &[f32] {
        let kvd = self.kv_dim;
        match &self.backing {
            Backing::Owned { k, .. } => &k[l][p * kvd..(p + 1) * kvd],
            Backing::Paged { arena, blocks } => {
                let bp = arena.block_positions;
                let base = ((l * 2) * bp + (p % bp)) * kvd;
                &blocks[p / bp][base..base + kvd]
            }
        }
    }

    /// One cached V row (`kv_dim` floats) for layer `l`, position `p`.
    #[inline]
    pub(crate) fn v_row(&self, l: usize, p: usize) -> &[f32] {
        let kvd = self.kv_dim;
        match &self.backing {
            Backing::Owned { v, .. } => &v[l][p * kvd..(p + 1) * kvd],
            Backing::Paged { arena, blocks } => {
                let bp = arena.block_positions;
                let base = ((l * 2 + 1) * bp + (p % bp)) * kvd;
                &blocks[p / bp][base..base + kvd]
            }
        }
    }

    /// One layer's cached K/V for positions `0..upto` (row-major
    /// `[upto, kv_dim]` slices). Only the owned backing is contiguous;
    /// paged callers must use the row accessors.
    pub(crate) fn layer_kv(&self, l: usize, upto: usize) -> (&[f32], &[f32]) {
        let n = upto * self.kv_dim;
        match &self.backing {
            Backing::Owned { k, v } => (&k[l][..n], &v[l][..n]),
            Backing::Paged { .. } => {
                panic!("layer_kv needs contiguous storage; paged states expose k_row/v_row")
            }
        }
    }

    /// Commit the logical length after an extension wrote all layers.
    pub(crate) fn commit(&mut self, len: usize) {
        debug_assert!(len <= self.max_seq);
        self.len = len;
    }
}

impl Clone for DecodeState {
    /// Clones are always owned compact copies (see
    /// [`snapshot`](DecodeState::snapshot)) so cloning a paged state
    /// never doubles arena pressure.
    fn clone(&self) -> DecodeState {
        self.snapshot(self.len)
    }
}

impl Drop for DecodeState {
    /// Paged states return their rented blocks to the arena — dropping
    /// a cancelled session is what brings occupancy back to zero.
    fn drop(&mut self) {
        if let Backing::Paged { arena, blocks } = &mut self.backing {
            for b in blocks.drain(..) {
                arena.release(b);
            }
        }
    }
}

/// One cached prompt: its decode state (exactly `prompt.len()` cached
/// positions) and the prompt's last-position logits row — everything a
/// worker needs to score option continuations without re-running the
/// prompt.
#[derive(Clone, Debug)]
pub struct PrefixEntry {
    pub state: DecodeState,
    pub last_row: Vec<f32>,
}

impl PrefixEntry {
    pub fn new(state: DecodeState, last_row: Vec<f32>) -> PrefixEntry {
        PrefixEntry { state, last_row }
    }
}

/// Bounded LRU from prompt token ids to [`PrefixEntry`]. Capacity 0
/// disables the cache (every lookup misses, inserts are dropped), so
/// callers never need a separate on/off switch.
#[derive(Debug)]
pub struct PrefixCache {
    cap: usize,
    tick: u64,
    map: BTreeMap<Vec<usize>, (u64, Arc<PrefixEntry>)>,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    pub fn new(cap: usize) -> PrefixCache {
        PrefixCache {
            cap,
            tick: 0,
            map: BTreeMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Look up a prompt, refreshing its recency on hit.
    pub fn get(&mut self, prompt: &[usize]) -> Option<Arc<PrefixEntry>> {
        if self.cap == 0 {
            return None;
        }
        match self.map.get_mut(prompt) {
            Some(slot) => {
                self.tick += 1;
                slot.0 = self.tick;
                self.hits += 1;
                metrics().cache_hits.inc();
                Some(Arc::clone(&slot.1))
            }
            None => {
                self.misses += 1;
                metrics().cache_misses.inc();
                None
            }
        }
    }

    /// Insert (or refresh) a prompt's entry, evicting the
    /// least-recently-used entry when at capacity.
    pub fn insert(&mut self, prompt: Vec<usize>, entry: PrefixEntry) {
        if self.cap == 0 {
            return;
        }
        if !self.map.contains_key(&prompt) && self.map.len() >= self.cap {
            let oldest = self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| k.clone());
            if let Some(key) = oldest {
                self.map.remove(&key);
            }
        }
        self.tick += 1;
        self.map.insert(prompt, (self.tick, Arc::new(entry)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PicoLlamaConfig {
        PicoLlamaConfig::test()
    }

    fn state_with(cfg: &PicoLlamaConfig, positions: usize, fill: f32) -> DecodeState {
        let mut st = DecodeState::new(cfg);
        let kvd = cfg.kv_dim();
        for l in 0..cfg.n_layers {
            let rows = vec![fill; positions * kvd];
            st.append_layer(l, 0, &rows, &rows);
        }
        st.commit(positions);
        st
    }

    #[test]
    fn truncate_is_logical_and_reextendable() {
        let cfg = cfg();
        let kvd = cfg.kv_dim();
        let mut st = state_with(&cfg, 5, 1.0);
        assert_eq!(st.len(), 5);
        assert_eq!(st.kv_bytes(), 2 * cfg.n_layers * 5 * kvd * 4);
        st.truncate(2);
        assert_eq!(st.len(), 2);
        // Re-extend over the truncated tail with different values.
        for l in 0..cfg.n_layers {
            let rows = vec![7.0; 3 * kvd];
            st.append_layer(l, 2, &rows, &rows);
        }
        st.commit(5);
        let (k, _) = st.layer_kv(0, 5);
        assert_eq!(k[0], 1.0, "prefix preserved");
        assert_eq!(k[2 * kvd], 7.0, "tail overwritten");
    }

    #[test]
    fn snapshot_and_copy_from_roundtrip() {
        let cfg = cfg();
        let st = state_with(&cfg, 4, 3.0);
        let snap = st.snapshot(3);
        assert_eq!(snap.len(), 3);
        let mut other = state_with(&cfg, 6, 9.0);
        other.copy_from(&snap);
        assert_eq!(other.len(), 3);
        let (k, v) = other.layer_kv(1, 3);
        assert!(k.iter().chain(v).all(|&x| x == 3.0));
    }

    #[test]
    #[should_panic(expected = "truncate")]
    fn truncate_beyond_len_panics() {
        let mut st = DecodeState::new(&cfg());
        st.truncate(1);
    }

    #[test]
    fn paged_rows_match_owned_rows() {
        let cfg = cfg();
        let kvd = cfg.kv_dim();
        let arena = Arc::new(KvArena::new(&cfg, 3, 16));
        let mut owned = DecodeState::new(&cfg);
        let mut paged = DecodeState::paged(&cfg, Arc::clone(&arena));
        // Write 7 positions in two ragged chunks (4 then 3), distinct
        // values per (layer, position, lane).
        let row = |l: usize, p: usize, which: usize| -> Vec<f32> {
            (0..kvd)
                .map(|d| (l * 1000 + p * 100 + which * 10 + d) as f32)
                .collect()
        };
        for (start, m) in [(0usize, 4usize), (4, 3)] {
            paged.reserve(start + m).unwrap();
            for l in 0..cfg.n_layers {
                let mut kc = Vec::new();
                let mut vc = Vec::new();
                for i in 0..m {
                    kc.extend(row(l, start + i, 0));
                    vc.extend(row(l, start + i, 1));
                }
                owned.append_layer(l, start, &kc, &vc);
                paged.append_layer(l, start, &kc, &vc);
            }
            owned.commit(start + m);
            paged.commit(start + m);
        }
        assert!(paged.is_paged() && !owned.is_paged());
        for l in 0..cfg.n_layers {
            for p in 0..7 {
                assert_eq!(owned.k_row(l, p), paged.k_row(l, p), "k layer {l} pos {p}");
                assert_eq!(owned.v_row(l, p), paged.v_row(l, p), "v layer {l} pos {p}");
            }
        }
        // Snapshots gather paged rows into owned contiguous storage.
        let snap = paged.snapshot(7);
        assert!(!snap.is_paged());
        for l in 0..cfg.n_layers {
            let (k, v) = snap.layer_kv(l, 7);
            let (ko, vo) = owned.layer_kv(l, 7);
            assert_eq!(k, ko);
            assert_eq!(v, vo);
        }
    }

    #[test]
    fn arena_occupancy_tracks_reserve_and_drop() {
        let cfg = cfg();
        let arena = Arc::new(KvArena::new(&cfg, 2, 4));
        assert_eq!(arena.blocks_for(0), 0);
        assert_eq!(arena.blocks_for(1), 1);
        assert_eq!(arena.blocks_for(5), 3);
        let mut a = DecodeState::paged(&cfg, Arc::clone(&arena));
        let mut b = DecodeState::paged(&cfg, Arc::clone(&arena));
        a.reserve(3).unwrap(); // 2 blocks
        b.reserve(4).unwrap(); // 2 blocks
        assert_eq!(arena.blocks_in_use(), 4);
        assert_eq!(a.blocks_held(), 2);
        // Pool is now exhausted; the next renter gets a typed error and
        // keeps what it already holds.
        let err = a.reserve(5).unwrap_err();
        assert_eq!(err.requested, 1);
        assert_eq!(err.total, 4);
        assert_eq!(a.blocks_held(), 2);
        // Cancelling a session (dropping its state) frees its blocks...
        drop(b);
        assert_eq!(arena.blocks_in_use(), 2);
        // ...which the retry then rents (recycled, not re-created).
        a.reserve(5).unwrap();
        assert_eq!(arena.blocks_in_use(), 3);
        drop(a);
        assert_eq!(arena.blocks_in_use(), 0);
    }

    #[test]
    fn paged_clone_is_owned_and_does_not_rent() {
        let cfg = cfg();
        let kvd = cfg.kv_dim();
        let arena = Arc::new(KvArena::new(&cfg, 4, 8));
        let mut st = DecodeState::paged(&cfg, Arc::clone(&arena));
        st.reserve(2).unwrap();
        for l in 0..cfg.n_layers {
            let rows = vec![5.0; 2 * kvd];
            st.append_layer(l, 0, &rows, &rows);
        }
        st.commit(2);
        let before = arena.blocks_in_use();
        let cl = st.clone();
        assert_eq!(arena.blocks_in_use(), before, "clone rents no blocks");
        assert!(!cl.is_paged());
        assert_eq!(cl.len(), 2);
        assert_eq!(cl.k_row(0, 1), st.k_row(0, 1));
    }

    #[test]
    fn copy_from_restores_into_paged_destination() {
        let cfg = cfg();
        let arena = Arc::new(KvArena::new(&cfg, 2, 8));
        let src = state_with(&cfg, 3, 6.5);
        let mut dst = DecodeState::paged(&cfg, Arc::clone(&arena));
        dst.copy_from(&src);
        assert_eq!(dst.len(), 3);
        for l in 0..cfg.n_layers {
            for p in 0..3 {
                assert_eq!(dst.k_row(l, p), src.k_row(l, p));
                assert_eq!(dst.v_row(l, p), src.v_row(l, p));
            }
        }
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let cfg = cfg();
        let entry = || PrefixEntry::new(DecodeState::new(&cfg), vec![0.0]);
        let mut cache = PrefixCache::new(2);
        cache.insert(vec![1], entry());
        cache.insert(vec![2], entry());
        assert!(cache.get(&[1]).is_some()); // refresh [1]; [2] is now LRU
        cache.insert(vec![3], entry());
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&[2]).is_none(), "LRU entry evicted");
        assert!(cache.get(&[1]).is_some());
        assert!(cache.get(&[3]).is_some());
        assert_eq!(cache.hits(), 3);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn zero_capacity_disables_cache() {
        let cfg = cfg();
        let mut cache = PrefixCache::new(0);
        cache.insert(vec![1], PrefixEntry::new(DecodeState::new(&cfg), vec![]));
        assert!(cache.get(&[1]).is_none());
        assert!(cache.is_empty());
        assert_eq!(cache.capacity(), 0);
    }

    #[test]
    fn reinsert_refreshes_existing_key_without_evicting() {
        let cfg = cfg();
        let entry = |x: f32| PrefixEntry::new(DecodeState::new(&cfg), vec![x]);
        let mut cache = PrefixCache::new(2);
        cache.insert(vec![1], entry(1.0));
        cache.insert(vec![2], entry(2.0));
        cache.insert(vec![1], entry(10.0)); // refresh, no eviction
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get(&[1]).unwrap().last_row, vec![10.0]);
        assert!(cache.get(&[2]).is_some());
    }
}
