//! Self-speculative decoding across the repo's own bit-widths:
//! a cheap INT2/INT4 **draft** engine proposes `k` greedy tokens, the
//! INT8/reference **target** engine verifies all of them in one batched
//! `forward_extend`, and the longest matching greedy prefix is
//! accepted (DESIGN.md §11).
//!
//! SplitQuantV2's core asset — one checkpoint packed at multiple
//! bit-widths with identical structure — is exactly what speculative
//! decoding needs: the draft and target share the vocabulary, the
//! tokenization, and the `DecodeState` geometry, so the only extra
//! machinery is a second (cheap) K/V cache and an O(1) `truncate`
//! rollback on mismatch.
//!
//! ## The draft/verify round
//!
//! State invariant between rounds: the target state caches every
//! position of `prompt + generated` **except the last generated token**
//! (`last`), which is decided but not yet fed — the same invariant the
//! plain greedy loop (`forward::generate_greedy_ops`) maintains. One
//! round:
//!
//! 1. **Catch-up + draft.** The draft state may lag the target (it is
//!    never rolled *forward* speculatively-wrong tokens). Feed it the
//!    known suffix it has not seen — ending with `last` — in one
//!    multi-token extend, then greedily propose `d₁ … dₘ`, each costing
//!    one single-position draft extend.
//! 2. **Batched verify.** One target `forward_extend` of the chunk
//!    `[last, d₁ … dₘ]` yields `m+1` logits rows. Row `i` is exactly
//!    the row target-only decoding would produce after
//!    `prompt + … + last + d₁ … dᵢ`.
//! 3. **Accept + bonus.** Accept `dᵢ` while the target's greedy choice
//!    for row `i-1` equals it; the first mismatching row (or the final
//!    row on full acceptance) contributes one **bonus** token — the
//!    target's own choice — so every round emits ≥ 1 token and the
//!    emitted stream is the target's greedy stream, token for token.
//! 4. **Rollback.** Truncate the target to the accepted prefix
//!    (`O(1)`) and the draft to the positions whose tokens are in the
//!    true output.
//!
//! ## Why the output is bit-for-bit identical
//!
//! Verification is *greedy*: a draft token is accepted iff it equals
//! [`greedy_token`](crate::model::forward::greedy_token) of the
//! target's logits at that position, and those
//! logits are computed by the same `forward_extend` the target-only
//! loop uses (chunked ≡ full is already property-tested per engine).
//! Every argmax — draft, verify, and plain decode — goes through
//! `eval::nan_safe_argmax`'s lowest-index tie-break, so there is no
//! row on which the two procedures can disagree. The property tests in
//! `rust/tests/specdec.rs` pin speculative ≡ target-only across draft
//! widths, `k`, and both CPU target engines.
//!
//! ## Adaptive `k`
//!
//! [`AdaptiveK`] shrinks the draft length when acceptance is poor
//! (halve below 50% acceptance) and recovers one step per fully
//! accepted round, capped at the configured `k`. The serving layer
//! additionally caps `k` when a session's deadline is near (a long
//! speculative chunk is wasted work if the deadline expires mid-round).
//! `k` only changes *speed*, never output: any `m ≥ 0` yields the same
//! tokens.

use std::sync::OnceLock;

use crate::kernels::KernelScratch;
use crate::model::decode::DecodeState;
use crate::model::forward::{
    forward_extend, greedy_token, prompt_pass, CkOps, ForwardOps, Workspace,
};
use crate::model::packed::PackedModel;
use crate::model::quantized::{quantize_model, Method};
use crate::model::{Checkpoint, PicoLlamaConfig};
use crate::obs;
use crate::quant::Bits;
use crate::split::SplitConfig;

use anyhow::{anyhow, Result};

/// Telemetry handles for the speculative decoder, looked up once.
struct SpecMetrics {
    drafted: obs::Counter,
    accepted: obs::Counter,
    rounds: obs::Counter,
    accept_len: obs::Histogram,
}

fn metrics() -> &'static SpecMetrics {
    static M: OnceLock<SpecMetrics> = OnceLock::new();
    M.get_or_init(|| SpecMetrics {
        drafted: obs::counter(obs::names::SPECDEC_DRAFT_TOKENS),
        accepted: obs::counter(obs::names::SPECDEC_ACCEPTED_TOKENS),
        rounds: obs::counter(obs::names::SPECDEC_ROUNDS),
        accept_len: obs::histogram(obs::names::SPECDEC_ACCEPT_LEN),
    })
}

/// Speculative-decoding policy knobs (`--draft-k` on the CLI).
#[derive(Clone, Debug)]
pub struct SpecConfig {
    /// Maximum draft tokens proposed per round (`k ≥ 1`).
    pub k: usize,
    /// Shrink `k` on low acceptance, recover on full acceptance.
    pub adaptive: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { k: 4, adaptive: true }
    }
}

impl SpecConfig {
    /// A fixed-`k` policy (adaptation off) — what the property tests
    /// use to sweep `k` deterministically.
    pub fn fixed(k: usize) -> Self {
        SpecConfig { k, adaptive: false }
    }
}

/// Acceptance accounting for one decode (merged across rounds).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft tokens proposed.
    pub drafted: u64,
    /// Draft tokens accepted by the verify pass.
    pub accepted: u64,
    /// Draft/verify rounds executed (rounds with `m == 0` — pure
    /// target steps — are not counted).
    pub rounds: u64,
    /// Tokens emitted (accepted + bonus tokens).
    pub emitted: u64,
}

impl SpecStats {
    /// Accepted / drafted (1.0 when nothing was drafted, so a pure
    /// target-step decode does not read as "0% acceptance").
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            1.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Fold another decode's stats into this one.
    pub fn merge(&mut self, other: &SpecStats) {
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.rounds += other.rounds;
        self.emitted += other.emitted;
    }
}

/// Shrink-on-miss / recover-on-hit controller for the draft length.
///
/// `propose()` is the `m` for the next round; `update(drafted,
/// accepted)` halves it when fewer than half the proposals survived
/// verification and grows it by one (capped at the configured `k`)
/// when every proposal survived. With `adaptive: false` it always
/// proposes the configured `k`.
#[derive(Clone, Debug)]
pub struct AdaptiveK {
    cur: usize,
    cap: usize,
    adaptive: bool,
}

impl AdaptiveK {
    pub fn new(cfg: &SpecConfig) -> AdaptiveK {
        let k = cfg.k.max(1);
        AdaptiveK { cur: k, cap: k, adaptive: cfg.adaptive }
    }

    /// Draft length for the next round.
    pub fn propose(&self) -> usize {
        self.cur
    }

    /// Feed one round's outcome back into the controller.
    pub fn update(&mut self, drafted: usize, accepted: usize) {
        if !self.adaptive || drafted == 0 {
            return;
        }
        if accepted == drafted {
            self.cur = (self.cur + 1).min(self.cap);
        } else if accepted * 2 < drafted {
            self.cur = (self.cur / 2).max(1);
        }
    }
}

/// Result of one draft/verify round.
#[derive(Clone, Debug)]
pub(crate) struct RoundOutcome {
    /// Tokens to append to the output: the accepted draft prefix plus
    /// the verify pass's bonus token — always ≥ 1 token.
    pub tokens: Vec<usize>,
    /// Draft tokens accepted (`tokens.len() - 1`).
    pub accepted: usize,
    /// Draft tokens proposed this round (`m`).
    pub drafted: usize,
}

/// One draft/verify/accept/rollback round (module doc, steps 1–4).
///
/// `seq` is `prompt + generated so far`; its final element is the
/// pending token — decided but not yet fed to the target. On entry the
/// target state caches exactly `seq.len() - 1` positions and the draft
/// state caches a (possibly shorter) prefix of the same sequence. On
/// exit both invariants are restored with `outcome.tokens` appended to
/// the logical sequence.
///
/// `m == 0` degenerates to a plain single-token target step (the draft
/// engine is not touched), which is how the decode loop finishes a
/// budget whose remainder is a single token.
pub(crate) fn spec_round<O: ForwardOps>(
    target: &mut O,
    draft: &PackedModel,
    draft_scratch: &mut KernelScratch,
    seq: &[usize],
    m: usize,
    ws: &mut Workspace,
    tstate: &mut DecodeState,
    dstate: &mut DecodeState,
) -> Result<RoundOutcome> {
    let p = tstate.len();
    debug_assert_eq!(p + 1, seq.len(), "target state must cache seq minus the pending token");
    debug_assert!(dstate.len() <= p, "draft state ahead of target");
    let last = *seq.last().expect("seq holds at least the pending token");

    // 1. Catch-up + draft: feed the draft the suffix it has not seen
    // (ending with `last`), then propose m tokens one extend at a time.
    let mut drafts = Vec::with_capacity(m);
    if m > 0 {
        if let Some(msg) =
            crate::util::failpoint::trigger(crate::util::failpoint::sites::SPECDEC_CATCH_UP)
        {
            anyhow::bail!("{msg}");
        }
        let start = dstate.len();
        let mut logits = draft.forward_extend(&seq[start..], start, ws, draft_scratch, dstate)?;
        loop {
            let d = greedy_token(logits.row(logits.shape()[0] - 1));
            drafts.push(d);
            if drafts.len() == m {
                break;
            }
            logits = draft.forward_extend(&[d], dstate.len(), ws, draft_scratch, dstate)?;
        }
    }

    // 2. Batched verify: one target extend over [last, d1..dm].
    let mut chunk = Vec::with_capacity(m + 1);
    chunk.push(last);
    chunk.extend_from_slice(&drafts);
    let verify = forward_extend(target, &chunk, p, ws, tstate)?;

    // 3. Accept the longest greedy-matching prefix + the bonus token.
    let mut accepted = 0;
    while accepted < m && greedy_token(verify.row(accepted)) == drafts[accepted] {
        accepted += 1;
    }
    let bonus = greedy_token(verify.row(accepted));
    let mut tokens = drafts;
    tokens.truncate(accepted);
    tokens.push(bonus);

    // 4. Rollback: the target keeps prefix + last + accepted drafts
    // (the bonus token becomes the next round's pending token); the
    // draft keeps only positions whose tokens are in the true output.
    tstate.truncate(p + 1 + accepted);
    dstate.truncate(dstate.len().min(p + 1 + accepted));

    if m > 0 {
        let sm = metrics();
        sm.drafted.add(m as u64);
        sm.accepted.add(accepted as u64);
        sm.rounds.inc();
        sm.accept_len.record(accepted as u64);
    }
    Ok(RoundOutcome { tokens, accepted, drafted: m })
}

/// Speculative twin of `forward::generate_greedy_ops`: same prompt
/// handling, same stop conditions, same tokens — proven bit-for-bit in
/// `rust/tests/specdec.rs` — but decoded in draft/verify rounds.
///
/// The caller owns both decode states (paged or owned; the serving
/// path rents both from the same `KvArena`) and the draft's kernel
/// scratch; `ws` is shared between the engines because draft and
/// target forwards never interleave within a round step.
#[allow(clippy::too_many_arguments)]
pub(crate) fn generate_greedy_spec_ops<O: ForwardOps>(
    target: &mut O,
    draft: &PackedModel,
    draft_scratch: &mut KernelScratch,
    prompt: &[usize],
    n_new: usize,
    ctrl: &mut AdaptiveK,
    ws: &mut Workspace,
    tstate: &mut DecodeState,
    dstate: &mut DecodeState,
    stats: &mut SpecStats,
) -> Result<Vec<usize>> {
    let max_seq = target.config().max_seq;
    if n_new == 0 || prompt.len() >= max_seq {
        return Ok(Vec::new());
    }
    // Exactly the plain loop's stop conditions, folded into one bound.
    let total = n_new.min(max_seq - prompt.len());
    let row = prompt_pass(target, prompt, ws, tstate)?;
    dstate.reset();
    let mut seq = prompt.to_vec();
    seq.push(greedy_token(&row));
    stats.emitted += 1;
    let mut produced = 1;
    while produced < total {
        // A round emits up to m+1 tokens; cap m so it never overshoots
        // the budget (which also keeps every speculative position
        // strictly inside max_seq).
        let m = ctrl.propose().min(total - produced - 1);
        let out = spec_round(target, draft, draft_scratch, &seq, m, ws, tstate, dstate)?;
        ctrl.update(out.drafted, out.accepted);
        stats.drafted += out.drafted as u64;
        stats.accepted += out.accepted as u64;
        stats.rounds += (out.drafted > 0) as u64;
        stats.emitted += out.tokens.len() as u64;
        produced += out.tokens.len();
        seq.extend_from_slice(&out.tokens);
    }
    Ok(seq.split_off(prompt.len()))
}

/// Require identical model geometry between draft and target — the
/// precondition for sharing prompts, positions, and the verify chunk.
pub fn check_draft_compat(draft: &PicoLlamaConfig, target: &PicoLlamaConfig) -> Result<()> {
    let same = draft.vocab == target.vocab
        && draft.d_model == target.d_model
        && draft.n_layers == target.n_layers
        && draft.n_heads == target.n_heads
        && draft.n_kv_heads == target.n_kv_heads
        && draft.d_ff == target.d_ff
        && draft.max_seq == target.max_seq;
    if same {
        Ok(())
    } else {
        Err(anyhow!(
            "draft/target model geometry mismatch: draft {draft:?} vs target {target:?}"
        ))
    }
}

/// A draft engine plus policy: the user-facing entry point for
/// speculative generation outside the server (benches, `eval
/// --speculative`, examples). The serving path reuses the same
/// `spec_round` core per continuous-batching step instead.
#[derive(Clone, Debug)]
pub struct SpecDecoder {
    draft: PackedModel,
    cfg: SpecConfig,
}

impl SpecDecoder {
    /// Wrap an already-packed draft model.
    pub fn new(draft: PackedModel, cfg: SpecConfig) -> Result<SpecDecoder> {
        if cfg.k == 0 {
            return Err(anyhow!("draft k must be ≥ 1"));
        }
        Ok(SpecDecoder { draft, cfg })
    }

    /// Quantize a draft at `bits` (SplitQuantV2 planes) from the same
    /// checkpoint the target was built from — the "self-speculative"
    /// construction: one model, two bit-widths.
    pub fn from_checkpoint(ck: &Checkpoint, bits: Bits, cfg: SpecConfig) -> Result<SpecDecoder> {
        let qm = quantize_model(ck, bits, &Method::SplitQuant(SplitConfig::default()))?;
        SpecDecoder::new(PackedModel::from_qmodel(&qm)?, cfg)
    }

    pub fn draft_model(&self) -> &PackedModel {
        &self.draft
    }

    pub fn config(&self) -> &SpecConfig {
        &self.cfg
    }

    /// Speculative greedy generation against a **packed** target
    /// (e.g. INT8). Returns the generated tokens — bit-identical to
    /// `target.generate_greedy` — plus the acceptance stats.
    #[allow(clippy::too_many_arguments)]
    pub fn generate_packed(
        &self,
        target: &PackedModel,
        prompt: &[usize],
        n_new: usize,
        ws: &mut Workspace,
        target_scratch: &mut KernelScratch,
        draft_scratch: &mut KernelScratch,
        tstate: &mut DecodeState,
        dstate: &mut DecodeState,
    ) -> Result<(Vec<usize>, SpecStats)> {
        check_draft_compat(&self.draft.config, &target.config)?;
        let mut ctrl = AdaptiveK::new(&self.cfg);
        let mut stats = SpecStats::default();
        let mut ops = target.ops(target_scratch);
        let toks = generate_greedy_spec_ops(
            &mut ops,
            &self.draft,
            draft_scratch,
            prompt,
            n_new,
            &mut ctrl,
            ws,
            tstate,
            dstate,
            &mut stats,
        )?;
        Ok((toks, stats))
    }

    /// Speculative greedy generation against the **reference** f32
    /// target — bit-identical to `forward::generate_greedy` on `ck`.
    #[allow(clippy::too_many_arguments)]
    pub fn generate_reference(
        &self,
        ck: &Checkpoint,
        prompt: &[usize],
        n_new: usize,
        ws: &mut Workspace,
        draft_scratch: &mut KernelScratch,
        tstate: &mut DecodeState,
        dstate: &mut DecodeState,
    ) -> Result<(Vec<usize>, SpecStats)> {
        check_draft_compat(&self.draft.config, &ck.config)?;
        let mut ctrl = AdaptiveK::new(&self.cfg);
        let mut stats = SpecStats::default();
        let mut ops = CkOps::new(ck);
        let toks = generate_greedy_spec_ops(
            &mut ops,
            &self.draft,
            draft_scratch,
            prompt,
            n_new,
            &mut ctrl,
            ws,
            tstate,
            dstate,
            &mut stats,
        )?;
        Ok((toks, stats))
    }
}

/// Per-session speculative state for the continuous-batching server:
/// the session's draft K/V (rented from the same arena as the target
/// state), its adaptive-`k` controller, and its acceptance stats.
#[derive(Debug)]
pub(crate) struct SpecSession {
    pub dstate: DecodeState,
    pub ctrl: AdaptiveK,
    pub stats: SpecStats,
}

impl SpecSession {
    pub(crate) fn new(cfg: &SpecConfig, dstate: DecodeState) -> SpecSession {
        SpecSession { dstate, ctrl: AdaptiveK::new(cfg), stats: SpecStats::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::generate_greedy;
    use crate::model::PicoLlamaConfig;

    fn ck() -> Checkpoint {
        let mut ck = Checkpoint::random_init(&PicoLlamaConfig::test(), 23);
        ck.amplify_outliers(0.002, 8.0, 11);
        ck
    }

    #[test]
    fn adaptive_k_shrinks_and_recovers() {
        let mut c = AdaptiveK::new(&SpecConfig { k: 8, adaptive: true });
        assert_eq!(c.propose(), 8);
        c.update(8, 1); // 12.5% acceptance → halve
        assert_eq!(c.propose(), 4);
        c.update(4, 0);
        assert_eq!(c.propose(), 2);
        c.update(2, 2); // full acceptance → +1
        assert_eq!(c.propose(), 3);
        for _ in 0..20 {
            c.update(3, 3);
        }
        assert_eq!(c.propose(), 8, "recovery is capped at the configured k");
        c.update(0, 0); // m == 0 rounds never adapt
        assert_eq!(c.propose(), 8);
        let mut fixed = AdaptiveK::new(&SpecConfig::fixed(4));
        fixed.update(4, 0);
        assert_eq!(fixed.propose(), 4, "fixed policy never adapts");
    }

    #[test]
    fn reference_target_speculative_matches_plain_greedy() {
        let ck = ck();
        let dec = SpecDecoder::from_checkpoint(&ck, Bits::Int4, SpecConfig::default()).unwrap();
        let mut ws = Workspace::new(&ck.config, ck.config.max_seq);
        let mut dscratch = dec.draft_model().prewarmed_scratch();
        for prompt in [vec![1usize, 5, 9], vec![2usize]] {
            let want = generate_greedy(&ck, &prompt, 12, &mut ws).unwrap();
            let mut ts = DecodeState::new(&ck.config);
            let mut ds = DecodeState::new(&ck.config);
            let (got, stats) = dec
                .generate_reference(&ck, &prompt, 12, &mut ws, &mut dscratch, &mut ts, &mut ds)
                .unwrap();
            assert_eq!(got, want, "speculative diverged from target-only greedy");
            assert_eq!(stats.emitted as usize, got.len());
            assert!(stats.accepted <= stats.drafted);
        }
    }

    #[test]
    fn single_token_budget_never_drafts() {
        let ck = ck();
        let dec = SpecDecoder::from_checkpoint(&ck, Bits::Int4, SpecConfig::default()).unwrap();
        let mut ws = Workspace::new(&ck.config, ck.config.max_seq);
        let mut dscratch = dec.draft_model().prewarmed_scratch();
        let mut ts = DecodeState::new(&ck.config);
        let mut ds = DecodeState::new(&ck.config);
        let (got, stats) = dec
            .generate_reference(&ck, &[3, 1, 4], 1, &mut ws, &mut dscratch, &mut ts, &mut ds)
            .unwrap();
        assert_eq!(got, generate_greedy(&ck, &[3, 1, 4], 1, &mut ws).unwrap());
        assert_eq!(stats.drafted, 0, "a 1-token budget is a pure target step");
        assert_eq!(stats.rounds, 0);
    }

    #[test]
    fn empty_and_overlong_prompts_mirror_plain_greedy() {
        let ck = ck();
        let dec = SpecDecoder::from_checkpoint(&ck, Bits::Int4, SpecConfig::default()).unwrap();
        let mut ws = Workspace::new(&ck.config, ck.config.max_seq);
        let mut dscratch = dec.draft_model().prewarmed_scratch();
        let mut ts = DecodeState::new(&ck.config);
        let mut ds = DecodeState::new(&ck.config);
        let at_edge = vec![1usize; ck.config.max_seq];
        let (got, _) = dec
            .generate_reference(&ck, &at_edge, 4, &mut ws, &mut dscratch, &mut ts, &mut ds)
            .unwrap();
        assert!(got.is_empty(), "prompt at max_seq generates nothing");
        let (none, _) = dec
            .generate_reference(&ck, &[1, 2], 0, &mut ws, &mut dscratch, &mut ts, &mut ds)
            .unwrap();
        assert!(none.is_empty());
    }

    #[test]
    fn draft_compat_rejects_mismatched_geometry() {
        let ck = ck();
        let mut other = PicoLlamaConfig::test();
        other.d_model *= 2;
        assert!(check_draft_compat(&ck.config, &ck.config).is_ok());
        assert!(check_draft_compat(&other, &ck.config).is_err());
    }
}
