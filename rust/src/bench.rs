//! Criterion-lite: the benchmark harness used by every `benches/*.rs`
//! target (the offline build has no criterion crate). Provides warmup,
//! repeated timed runs, summary statistics, and a `black_box` to defeat
//! constant folding. Benches are `harness = false` binaries that call
//! into this module and print both human tables and machine-readable
//! `BENCH-JSON` lines that EXPERIMENTS.md extraction scripts consume.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::timer::format_duration;

/// Re-export of the std black box under the criterion name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Configuration for a measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop early once this much time has been spent measuring.
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 100,
            target_time: Duration::from_secs(2),
        }
    }
}

impl BenchConfig {
    /// Fast profile for expensive end-to-end benches.
    pub fn heavy() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 10,
            target_time: Duration::from_secs(5),
        }
    }

    /// One-shot (workloads that are themselves long experiments).
    pub fn once() -> Self {
        Self {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 1,
            target_time: Duration::ZERO,
        }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.secs.mean)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.secs.mean)),
            ("median_s", Json::num(self.secs.median)),
            ("std_s", Json::num(self.secs.std)),
            ("min_s", Json::num(self.secs.min)),
            ("max_s", Json::num(self.secs.max)),
        ])
    }
}

/// A named group of benchmarks printed together.
pub struct Bench {
    group: String,
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Self {
            group: group.to_string(),
            config: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    pub fn with_config(group: &str, config: BenchConfig) -> Self {
        Self {
            group: group.to_string(),
            config,
            results: Vec::new(),
        }
    }

    /// Measure `f` repeatedly; returns the mean duration.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Duration {
        for _ in 0..self.config.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        while samples.len() < self.config.min_iters
            || (samples.len() < self.config.max_iters
                && started.elapsed() < self.config.target_time)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            secs: Summary::of(&samples),
        };
        let mean = res.mean();
        println!(
            "  {:<44} {:>12} ±{:>10}  ({} iters)",
            name,
            format_duration(mean),
            format_duration(Duration::from_secs_f64(res.secs.std)),
            res.iters
        );
        println!("BENCH-JSON {}", json_line(&self.group, &res));
        self.results.push(res);
        mean
    }

    /// Record an externally-measured scalar (e.g. accuracy) alongside the
    /// timing results, in the same machine-readable stream.
    pub fn record_metric(&self, name: &str, value: f64, unit: &str) {
        let j = Json::obj(vec![
            ("group", Json::str(self.group.clone())),
            ("metric", Json::str(name)),
            ("value", Json::num(value)),
            ("unit", Json::str(unit)),
        ]);
        println!("BENCH-JSON {}", j.to_string());
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

fn json_line(group: &str, r: &BenchResult) -> String {
    let mut j = r.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("group".into(), Json::str(group));
    }
    j.to_string()
}

/// Standard entry header so all bench binaries look alike.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_summarizes() {
        let mut b = Bench::with_config(
            "t",
            BenchConfig {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 5,
                target_time: Duration::from_millis(10),
            },
        );
        let d = b.run("noop", || 1 + 1);
        assert!(d < Duration::from_millis(50));
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 3);
    }

    #[test]
    fn once_config_runs_once() {
        let mut b = Bench::with_config("t", BenchConfig::once());
        let mut count = 0;
        b.run("counted", || count += 1);
        assert_eq!(count, 1);
    }

    #[test]
    fn json_line_is_valid() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            secs: Summary::of(&[1.0, 2.0, 3.0]),
        };
        let line = json_line("g", &r);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("group").unwrap().as_str().unwrap(), "g");
        assert_eq!(v.get("iters").unwrap().as_usize().unwrap(), 3);
    }
}
